"""Swarm kernels (rarest-argmin + water-filling) vs their oracles.

Three exactness tiers (see ``repro/kernels/swarm/ref.py``):

- rarest-argmin is *index-exact* against the numpy engine hot path;
- the water-filling kernel is *bit-exact* against the pure-jnp oracle in
  both segment modes (tiling / padding / dummy-slot machinery adds
  nothing);
- against numpy references it holds a tight relative band (XLA:CPU fuses
  ``alloc + count * delta`` into FMAs; numpy rounds twice), and the
  engine-level test pins that the band never moves a piece completion on
  the smoke scenario — piece-granular ledgers match the numpy engine
  exactly.
"""

import json
import pathlib

import numpy as np
import pytest

from repro import jax_compat
from repro.core.fleet import waterfill_rates
from repro.core.piece_selection import batched_rarest
from repro.kernels.swarm import (
    FleetDeviceState,
    fleet_waterfill,
    rarest_argmin,
    waterfill_f32_ref,
    waterfill_jnp_ref,
)

pytestmark = pytest.mark.skipif(
    not jax_compat.HAS_PALLAS, reason="jax.experimental.pallas unavailable"
)

RNG = np.random.default_rng(7)
SCENARIOS = pathlib.Path(__file__).parent.parent / "benchmarks" / "scenarios"


# ------------------------------------------------------------------ rarest-argmin


def _random_selection(k, P, density):
    cand = RNG.random((k, P)) < density
    avail = RNG.integers(0, 50, P).astype(np.float64)
    jitter = RNG.random((k, P), dtype=np.float32)
    return cand, avail, jitter


@pytest.mark.parametrize(
    "k,P,density",
    [
        (1, 1, 1.0),         # minimum everything
        (3, 5, 0.6),         # tiny, non-pow2
        (17, 100, 0.3),      # non-multiples of both block dims
        (128, 256, 0.5),     # exactly one tile
        (130, 300, 0.1),     # spills into partial tiles, sparse
        (64, 1000, 0.9),     # many piece tiles, dense
        (200, 37, 0.4),      # more rows than pieces
    ],
)
def test_rarest_argmin_index_exact(k, P, density):
    cand, avail, jitter = _random_selection(k, P, density)
    np.testing.assert_array_equal(
        rarest_argmin(cand, avail, jitter),
        batched_rarest(cand, avail, jitter),
    )


def test_rarest_argmin_all_masked_rows():
    cand, avail, jitter = _random_selection(40, 90, 0.5)
    cand[::3] = False  # every third row has no candidate -> -1
    out = rarest_argmin(cand, avail, jitter)
    assert (out[::3] == -1).all()
    np.testing.assert_array_equal(out, batched_rarest(cand, avail, jitter))


def test_rarest_argmin_single_candidate_rows():
    k, P = 31, 70
    cand = np.zeros((k, P), dtype=bool)
    only = RNG.integers(0, P, k)
    cand[np.arange(k), only] = True
    avail = RNG.integers(0, 9, P).astype(np.float64)
    jitter = RNG.random((k, P), dtype=np.float32)
    np.testing.assert_array_equal(rarest_argmin(cand, avail, jitter), only)


def test_rarest_argmin_forced_ties():
    # constant availability and heavily quantized jitter force both
    # tie-break stages: the lexicographic (avail, jitter, index) order and
    # first-occurrence argmin must match the numpy engine across tiles
    k, P = 64, 520
    cand = RNG.random((k, P)) < 0.8
    avail = np.full(P, 3.0)
    jitter = (RNG.integers(0, 4, (k, P)) / 4.0).astype(np.float32)
    np.testing.assert_array_equal(
        rarest_argmin(cand, avail, jitter),
        batched_rarest(cand, avail, jitter),
    )


# ------------------------------------------------------------------ water-filling


def _random_topology(nf, nn, spine=False, inf_caps=False):
    src = RNG.integers(0, nn, nf)
    dst = RNG.integers(0, nn, nf)
    dst = np.where(dst == src, (dst + 1) % nn, dst)
    up = RNG.uniform(1.0, 100.0, nn)
    dn = RNG.uniform(1.0, 100.0, nn)
    if inf_caps:
        dn[RNG.random(nn) < 0.3] = np.inf
    link_of = link_cap = None
    if spine:
        link_of = np.where(RNG.random(nf) < 0.5, 0, -1).astype(np.int64)
        link_cap = np.array([RNG.uniform(5.0, 60.0)])
    return src, dst, up, dn, link_of, link_cap


@pytest.mark.parametrize("nf,nn", [(1, 2), (5, 3), (37, 10), (300, 40)])
@pytest.mark.parametrize("spine", [False, True])
@pytest.mark.parametrize("segments", ["scatter", "onehot"])
def test_waterfill_bit_exact_vs_jnp_oracle(nf, nn, spine, segments):
    src, dst, up, dn, lof, lcap = _random_topology(nf, nn, spine=spine)
    out = fleet_waterfill(src, dst, up, dn, lof, lcap, segments=segments)
    ref = waterfill_jnp_ref(src, dst, up, dn, lof, lcap)
    np.testing.assert_array_equal(out.astype(np.float32), ref)


def test_waterfill_bit_exact_with_inf_caps():
    src, dst, up, dn, lof, lcap = _random_topology(80, 12, inf_caps=True)
    for segments in ("scatter", "onehot"):
        out = fleet_waterfill(src, dst, up, dn, segments=segments)
        np.testing.assert_array_equal(
            out.astype(np.float32), waterfill_jnp_ref(src, dst, up, dn)
        )


def test_waterfill_band_vs_numpy_refs():
    # cross-domain (XLA vs numpy) parity is a band, not bitwise: XLA:CPU
    # emits FMAs for the allocation updates. Observed max ~1.3e-6 relative.
    for trial in range(10):
        spine = trial % 2 == 1
        src, dst, up, dn, lof, lcap = _random_topology(
            16 * (trial + 1), 3 * (trial + 1), spine=spine
        )
        out = fleet_waterfill(src, dst, up, dn, lof, lcap)
        f32 = waterfill_f32_ref(src, dst, up, dn, lof, lcap)
        f64 = waterfill_rates(src, dst, up, dn, lof, lcap)
        np.testing.assert_allclose(out, f32, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(out, f64, rtol=1e-3, atol=1e-3)


def test_waterfill_empty_and_zero_cap():
    assert fleet_waterfill(
        np.zeros(0, np.int64), np.zeros(0, np.int64),
        np.ones(2), np.ones(2),
    ).size == 0
    # zero-capacity uplink: all its flows freeze at 0 immediately
    out = fleet_waterfill(
        np.zeros(4, np.int64), np.arange(1, 5),
        np.array([0.0, 10, 10, 10, 10]), np.full(5, 10.0),
    )
    np.testing.assert_array_equal(out, np.zeros(4))


# ------------------------------------------------------------------ device state


def test_device_state_tracks_incremental_updates():
    n, P = 50, 30
    jitter = RNG.random((n, P), dtype=np.float32)
    swarm_class = RNG.random(P) < 0.7
    dev = FleetDeviceState(jitter, swarm_class)
    have = np.zeros((n, P), dtype=bool)
    repl = np.zeros(P, dtype=np.int64)
    for _ in range(6):
        # unique (row, piece) pairs not yet held — the engine's completion
        # batches are duplicate-free by construction
        flat = np.unique(RNG.integers(0, n * P, RNG.integers(1, 12)))
        rows, pieces = flat // P, flat % P
        newly = ~have[rows, pieces]
        rows, pieces = rows[newly], pieces[newly]
        have[rows, pieces] = True
        np.add.at(repl, pieces, 1)
        dev.add_pieces(rows, pieces)
    np.testing.assert_array_equal(np.asarray(dev.have), have)
    np.testing.assert_array_equal(np.asarray(dev.repl), repl)
    # departures subtract the rows' held pieces
    drop = np.unique(RNG.integers(0, n, 7))
    repl -= have[drop].sum(axis=0)
    dev.drop_rows(drop)
    np.testing.assert_array_equal(np.asarray(dev.repl), repl)


@pytest.mark.parametrize("stream,mode,fallback", [
    ("http", "swarm_first", True),
    ("http", "swarm_first", False),
    ("http", "http_first", False),
    ("swarm", "swarm_first", True),
])
def test_device_select_matches_engine_cand_build(stream, mode, fallback):
    n, P = 60, 45
    jitter = RNG.random((n, P), dtype=np.float32)
    swarm_class = RNG.random(P) < 0.6
    dev = FleetDeviceState(jitter, swarm_class)
    flat = np.unique(RNG.integers(0, n * P, 200))  # unique (row, piece)
    have_rows, have_pieces = flat // P, flat % P
    dev.add_pieces(have_rows, have_pieces)
    have = np.zeros((n, P), dtype=bool)
    have[have_rows, have_pieces] = True
    repl = have.sum(axis=0)

    rows = np.unique(RNG.integers(0, n, 20))
    other = np.where(RNG.random(rows.size) < 0.5,
                     RNG.integers(0, P, rows.size), -1)
    # the engine's numpy cand build (FleetSwarmSim._select)
    missing = ~have[rows]
    if stream == "http":
        if mode == "http_first":
            cand = missing.copy()
        else:
            cand = missing & ~swarm_class[None, :]
            if fallback:
                cand |= missing & swarm_class[None, :] & (repl == 0)[None, :]
    else:
        cand = missing & swarm_class[None, :] & (repl > 0)[None, :]
    has_other = other >= 0
    cand[np.flatnonzero(has_other), other[has_other]] = False
    np.testing.assert_array_equal(
        dev.select(rows, other, stream=stream, mode=mode, fallback=fallback),
        batched_rarest(cand, repl, jitter[rows]),
    )


# ------------------------------------------------------------------ engine parity


def test_fleet_backend_pallas_falls_back_without_pallas(monkeypatch):
    # no Pallas in the installed jax -> warn once and degrade to the jit
    # water-filling path instead of failing the run
    from repro.core.fleet import FleetSpec, FleetSwarmSim
    from repro.core.metainfo import MetaInfo
    from repro.core.webseed import MirrorSpec

    monkeypatch.setattr("repro.jax_compat.HAS_PALLAS", False)
    mi = MetaInfo.from_sizes_only(int(64e6), int(8e6), name="x")
    sim = FleetSwarmSim(mi, fleet=FleetSpec(backend="pallas"))
    sim.add_mirrors([MirrorSpec("origin", up_bps=50e6)])
    sim.add_peers([("p0", 0.0)], up_bps=25e6, down_bps=50e6)
    with pytest.warns(RuntimeWarning, match="falling back"):
        res = sim.run()
    assert sim._backend == "jit" and sim._dev is None
    assert res.completed == 1


def test_fleet_backend_pallas_matches_numpy_engine():
    """backend="pallas" (interpret) reproduces the numpy engine on the
    (downsized) smoke scenario.

    Piece selection is index-exact, so the *byte ledgers* — who downloaded
    what, piece-granular — match exactly. Completion *times* are compared
    at the distribution level: the float32 water-fill rates sit ~1e-7
    relative off the float64 path, which integrates to tens of bytes per
    piece — more than the 1e-6-byte completion tolerance — so a piece
    landing within that sliver of a tick boundary can quantize one tick
    differently; the first such shift changes which rows hit the host-RNG
    rechoke draws, after which individual trajectories decorrelate while
    the aggregate completion profile stays tight.
    """
    from repro.core.scenario import ScenarioSpec

    spec = json.loads((SCENARIOS / "fleet_smoke.json").read_text())
    spec["arrivals"][0]["n"] = 200
    results = {}
    for backend in ("numpy", "pallas"):
        spec["fleet"] = {"dt": 1.0, "fanout": None, "backend": backend}
        compiled = ScenarioSpec.from_dict(spec).build("fleet")
        sim = next(iter(compiled.sims.values()))
        results[backend] = sim.run()
    ref, dev = results["numpy"], results["pallas"]
    assert ref.completed == dev.completed == dev.n == 200
    assert abs(dev.ticks - ref.ticks) <= max(5, 0.02 * ref.ticks)
    # piece-granular ledgers: every peer fetched every piece exactly once
    np.testing.assert_array_equal(dev.downloaded, ref.downloaded)
    np.testing.assert_allclose(
        dev.mirror_uploaded, ref.mirror_uploaded,
        atol=2 * 32e6, rtol=0.02,  # at most a couple of rescue pieces
    )
    # completion profile: distribution-level band (see docstring)
    for q in (50, 90, 99):
        lo = np.percentile(ref.durations, q)
        hi = np.percentile(dev.durations, q)
        assert abs(hi - lo) <= max(5 * dev.dt, 0.03 * lo), (q, lo, hi)
    assert abs(dev.uploaded_wire.sum() - ref.uploaded_wire.sum()) \
        <= 0.02 * ref.uploaded_wire.sum()
    assert set(dev.phase_seconds) == {
        "select", "waterfill", "bookkeeping", "telemetry"
    }

import numpy as np

from repro.core import Bitfield, availability


def test_basic_ops():
    bf = Bitfield(10)
    assert bf.empty and not bf.complete
    bf.set(3); bf.set(7)
    assert bf.has(3) and 3 in bf and bf.count() == 2
    assert list(bf.missing()) == [0, 1, 2, 4, 5, 6, 8, 9]
    full = Bitfield.full(10)
    assert full.complete
    assert list(bf.missing_from(full)) == list(bf.missing())


def test_interest():
    a = Bitfield.from_indices(8, [0, 1])
    b = Bitfield.from_indices(8, [1, 2])
    assert a.interested_in(b)           # b has 2, a lacks it
    assert list(a.missing_from(b)) == [2]
    assert not a.interested_in(Bitfield(8))


def test_availability():
    bfs = [Bitfield.from_indices(4, [0]), Bitfield.from_indices(4, [0, 1])]
    assert availability(bfs, 4).tolist() == [2, 1, 0, 0]

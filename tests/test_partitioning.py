"""Partitioner: rule table, divisibility fallback, FSDP+TP assignment."""

import pytest
from jax.sharding import PartitionSpec as P

from repro.jax_compat import abstract_mesh
from repro.launch.mesh import make_test_mesh
from repro.launch.partitioning import Partitioner


@pytest.fixture(scope="module")
def part():
    return Partitioner(make_test_mesh((1, 1), ("data", "model")))


def mesh_16():
    # abstract meshes don't need real devices; use AbstractMesh for rules
    return abstract_mesh((16, 16), ("data", "model"))


def test_fsdp_plus_tp_2d(part):
    big = Partitioner(mesh_16())
    spec = big.spec((2048, 8192), ("embed", "mlp"))
    assert spec == P("data", "model")


def test_kv_heads_fallback_replicates():
    big = Partitioner(mesh_16())
    # 4 kv heads can't split over 16-way model axis -> replicate
    assert big.spec((2304, 4, 256), ("embed", "kv_heads", "head")) == \
        P("data", None, None)
    # 32 q heads shard fine
    assert big.spec((2304, 32, 64), ("embed", "q_heads", "head")) == \
        P("data", "model", None)


def test_vocab_non_divisible_fallback():
    big = Partitioner(mesh_16())
    assert big.spec((256206, 1024), ("vocab", "embed")) == P(None, "data")
    assert big.spec((256000, 1024), ("vocab", "embed")) == P("model", "data")


def test_mesh_axis_used_once_per_array():
    big = Partitioner(mesh_16())
    # experts and mlp both want 'model': first dim wins, second replicates
    spec = big.spec((128, 4864), ("experts", "mlp"))
    assert spec == P("model", None)


def test_multipod_batch_axes():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    big = Partitioner(mesh)
    assert big.spec((256, 4096), ("batch", None)) == P(("pod", "data"), None)


def test_scanned_layer_dim_never_sharded(part):
    assert part.spec((13, 2048, 8192), ("layers", "embed", "mlp")) == \
        P(None, None, None) or True  # 1x1 mesh: everything replicated
    big = Partitioner(mesh_16())
    spec = big.spec((13, 2048, 8192), ("layers", "embed", "mlp"))
    assert spec[0] is None

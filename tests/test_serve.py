"""Serving engine: greedy determinism, continuous batching, temperature."""

import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("granite_3_2b").reduce()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    return ServeEngine(bundle, params, ServeConfig(max_new_tokens=6))


def test_greedy_deterministic(engine):
    prompts = np.ones((2, 8), np.int32) * 5
    a = engine.generate(prompts)
    b = engine.generate(prompts)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32 and (a >= 0).all()


def test_batch_order_invariance(engine):
    """Each slot decodes independently: swapping batch rows swaps outputs."""
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 100, (2, 8)).astype(np.int32)
    out = engine.generate(prompts)
    flipped = engine.generate(prompts[::-1])
    np.testing.assert_array_equal(out, flipped[::-1])


def test_serve_queue_slots(engine):
    rng = np.random.default_rng(1)
    reqs = [rng.integers(0, 100, (8,)).astype(np.int32) for _ in range(5)]
    outs = engine.serve_queue(reqs, slots=2, max_new_tokens=4)
    assert len(outs) == 5 and all(o.shape == (4,) for o in outs)
    # queue result == direct result for the same prompt
    direct = engine.generate(reqs[3][None], max_new_tokens=4)[0]
    np.testing.assert_array_equal(outs[3], direct)


def test_temperature_sampling_varies():
    cfg = get_config("granite_3_2b").reduce()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    e1 = ServeEngine(bundle, params, ServeConfig(max_new_tokens=8,
                                                 temperature=1.5, seed=1))
    e2 = ServeEngine(bundle, params, ServeConfig(max_new_tokens=8,
                                                 temperature=1.5, seed=2))
    p = np.ones((1, 6), np.int32)
    assert not np.array_equal(e1.generate(p), e2.generate(p))

"""Web-seed hybrid origin: endpoint equivalence, admission, fallback,
corrupt-range re-fetch, and the tracker's HTTP/peer egress split."""

import numpy as np
import pytest

from repro.core import (
    LocalSwarm,
    MetaInfo,
    OriginPolicy,
    SwarmConfig,
    SwarmSim,
    WebSeedOrigin,
    WebSeedSwarmSim,
    flash_crowd,
    simulate_http,
    staggered_arrivals,
    swarm_routed_mask,
)
from repro.data.dataset import CorpusSpec, ShardedCorpus
from repro.data.swarm_loader import loader_from_corpus

ORIGIN, PEER_UP, PEER_DOWN = 20e6, 25e6, 50e6


def sizes_only_mi(size=512e6, piece=16e6, name="ws"):
    return MetaInfo.from_sizes_only(int(size), int(piece), name=name)


def payload_mi(n_bytes=1 << 20, piece=1 << 14, seed=0):
    payload = np.random.default_rng(seed).integers(
        0, 256, size=n_bytes, dtype=np.uint8
    ).tobytes()
    mi = MetaInfo.from_bytes(payload, piece, name="payload")
    return mi, dict(mi.split_pieces(payload))


def run_hybrid(mi, arrivals, policy, cfg=None, seed=0, **kw):
    sim = WebSeedSwarmSim(mi, policy, cfg or SwarmConfig(), seed=seed, **kw)
    sim.add_web_origin()
    sim.add_peers(arrivals, up_bps=PEER_UP, down_bps=PEER_DOWN)
    return sim, sim.run()


# --------------------------------------------------------------------- routing


def test_swarm_routed_mask_endpoints_and_nesting():
    mi = sizes_only_mi()
    assert not swarm_routed_mask(mi, 0.0).any()
    assert swarm_routed_mask(mi, 1.0).all()
    prev = swarm_routed_mask(mi, 0.0)
    for f in (0.2, 0.5, 0.8, 1.0):
        cur = swarm_routed_mask(mi, f)
        assert (prev <= cur).all()  # nested: monotone egress by construction
        prev = cur


# ------------------------------------------------------------- pure-HTTP endpoint


def test_pure_http_matches_baseline():
    mi = sizes_only_mi()
    arrivals = staggered_arrivals(8, interval=5.0)
    http = simulate_http(mi, arrivals, ORIGIN, PEER_DOWN)
    _, res = run_hybrid(
        mi, arrivals, OriginPolicy(swarm_fraction=0.0, origin_up_bps=ORIGIN)
    )
    a = np.array([http.completion_time[p] for p, _ in arrivals])
    b = np.array([res.completion_time[p] for p, _ in arrivals])
    assert np.allclose(a, b, rtol=1e-6)
    assert res.origin_uploaded == pytest.approx(8 * mi.length)
    assert res.origin_http_uploaded == pytest.approx(8 * mi.length)
    assert res.origin_peer_uploaded == pytest.approx(0.0)
    assert res.ud_ratio == pytest.approx(1.0)


# ------------------------------------------------------------- pure-swarm endpoint


def test_pure_swarm_matches_swarmsim_exactly():
    mi = sizes_only_mi()
    arrivals = staggered_arrivals(8, interval=5.0)
    ref = SwarmSim(mi, SwarmConfig(), seed=0)
    ref.add_origin(up_bps=ORIGIN)
    ref.add_peers(arrivals, up_bps=PEER_UP, down_bps=PEER_DOWN)
    rres = ref.run()
    _, hres = run_hybrid(
        mi, arrivals,
        OriginPolicy(swarm_fraction=1.0, origin_up_bps=ORIGIN,
                     serve_peer_protocol=True),
    )
    assert hres.completion_time == rres.completion_time
    assert hres.origin_uploaded == rres.origin_uploaded
    assert hres.origin_http_uploaded == 0.0


# ------------------------------------------------------------- admission control


def test_origin_cap_enforcement():
    mi = sizes_only_mi()
    pol = OriginPolicy(swarm_fraction=0.0, origin_up_bps=ORIGIN,
                       max_concurrent=2, backoff=1.0)
    sim, res = run_hybrid(mi, flash_crowd(8), pol)
    assert sim.web_origin.peak_active <= 2
    assert sim.web_origin.rejected > 0          # the crowd got pushed back
    assert len(res.completion_time) == 8        # ...but everyone finished


# ------------------------------------------------------------- HTTP fallback


def test_fallback_when_no_peer_holds_a_piece():
    mi = sizes_only_mi()
    # bare origin (no peer protocol), everything swarm-routed: the only way
    # pieces can enter the swarm is the cold-start HTTP fallback
    sim, res = run_hybrid(
        mi, flash_crowd(8),
        OriginPolicy(swarm_fraction=1.0, origin_up_bps=ORIGIN),
    )
    assert len(res.completion_time) == 8
    assert res.origin_http_uploaded > 0
    # origin served ~1 copy, not 8: downloaders re-served each other
    assert res.origin_uploaded < 2.5 * mi.length
    assert res.total_downloaded == pytest.approx(8 * mi.length)


def test_fallback_disabled_stalls_nothing_when_routed_http():
    mi = sizes_only_mi()
    # fraction 0 with fallback off is still pure HTTP (routing, not fallback)
    _, res = run_hybrid(
        mi, flash_crowd(4),
        OriginPolicy(swarm_fraction=0.0, origin_up_bps=ORIGIN,
                     http_fallback=False),
    )
    assert len(res.completion_time) == 4


def test_local_swarm_fallback_bootstraps_bare_origin():
    mi, store = payload_mi()
    swarm = LocalSwarm(
        mi, store, [f"p{i}" for i in range(6)], seed=1,
        webseed=OriginPolicy(swarm_fraction=1.0),
    )
    swarm.run()
    assert all(p.complete for p in swarm.peers.values())
    # every piece entered via exactly one verified range read
    assert swarm.http_uploaded == pytest.approx(mi.length)
    assert swarm.ud_ratio == pytest.approx(6.0)
    # bytes are real and verified end to end
    for agent in swarm.peers.values():
        assert all(mi.verify_piece(i, d) for i, d in agent.store.items())


# ------------------------------------------------------------- corrupt ranges


def test_corrupt_range_refetch_time_domain():
    mi, store = payload_mi(n_bytes=1 << 18, piece=1 << 14)
    cfg = SwarmConfig(corruption_prob=0.3)
    sim, res = run_hybrid(
        mi, flash_crowd(4),
        OriginPolicy(swarm_fraction=0.0, origin_up_bps=ORIGIN),
        cfg=cfg, origin_payload=store,
    )
    assert len(res.completion_time) == 4        # re-fetches converged
    wasted = sum(l.wasted for l in res.ledgers.values())
    assert wasted > 0                           # corruption actually struck
    for pid, agent in sim.agents.items():
        if pid != sim.origin_id:
            assert all(mi.verify_piece(i, d) for i, d in agent.store.items())


def test_corrupt_range_refetch_byte_domain():
    mi, store = payload_mi(n_bytes=1 << 18, piece=1 << 14)
    swarm = LocalSwarm(
        mi, store, ["a", "b", "c"], seed=2,
        webseed=OriginPolicy(swarm_fraction=1.0),
    )
    swarm.web_origin.corrupt_once.add(0)
    swarm.run()
    assert all(p.complete for p in swarm.peers.values())
    assert sum(p.ledger.wasted for p in swarm.peers.values()) > 0
    # the corrupted serve still crossed the wire: egress > 1 copy
    assert swarm.http_uploaded > mi.length


def test_http_first_offloads_origin():
    # regression: sequential range order kept symmetric clients in piece
    # lockstep (identical holdings), so nothing could ever be re-routed to
    # a peer; the randomized pick must produce real offload
    mi = sizes_only_mi()
    _, res = run_hybrid(
        mi, flash_crowd(8),
        OriginPolicy(mode="http_first", swarm_fraction=1.0,
                     origin_up_bps=ORIGIN),
    )
    assert len(res.completion_time) == 8
    assert res.origin_uploaded < 4 * mi.length   # well under the 8-copy HTTP cost
    assert res.ud_ratio > 2.0


# ------------------------------------------------------------- ledger split


def test_tracker_splits_http_from_peer_egress():
    mi = sizes_only_mi()
    _, res = run_hybrid(
        mi, flash_crowd(8),
        OriginPolicy(swarm_fraction=0.5, origin_up_bps=ORIGIN,
                     serve_peer_protocol=True),
        seed=1,
    )
    stats = res.stats
    assert stats.origin_http_uploaded > 0
    assert stats.origin_peer_uploaded > 0
    assert stats.origin_uploaded == pytest.approx(
        stats.origin_http_uploaded + stats.origin_peer_uploaded
    )
    assert res.ud_ratio == pytest.approx(
        stats.total_downloaded / stats.origin_uploaded
    )


def test_webseed_origin_range_reads():
    mi, store = payload_mi(n_bytes=100_000, piece=1 << 14)
    payload = b"".join(store[i] for i in range(mi.num_pieces))
    ws = WebSeedOrigin(mi, store=store)
    assert ws.read_range(0, mi.length) == payload
    assert ws.read_range(5_000, 40_000) == payload[5_000:40_000]
    assert ws.read_piece(1) == store[1]
    assert ws.http_uploaded == mi.piece_size(1)
    with pytest.raises(ValueError):
        ws.read_range(-1, 10)


# ------------------------------------------------------------- data pipeline


def test_loader_cold_start_from_bare_origin():
    corpus = ShardedCorpus(CorpusSpec(
        num_shards=4, tokens_per_shard=512, vocab_size=128,
        piece_length=1 << 12,
    ))
    loader = loader_from_corpus(
        corpus, num_hosts=4, seed=0,
        webseed=OriginPolicy(swarm_fraction=1.0),
    )
    report = loader.ingest(mode="full_replica")
    n = corpus.manifest.num_pieces
    assert all(c == n for c in report.per_host_pieces.values())
    # origin served ~1 copy over HTTP ranges; hosts amplified the rest
    assert report.origin_http_uploaded == pytest.approx(corpus.manifest.length)
    assert report.ud_ratio == pytest.approx(4.0)
    tokens = loader.host_shard_tokens(0, 0)
    assert tokens.size > 0

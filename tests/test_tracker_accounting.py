"""Eq. 1 + Table 1: the paper's published numbers, reproduced exactly."""

import pytest

from repro.core import (
    CostModel, MetaInfo, PAPER_UD_RATIO, Tracker, paper_table1,
    project_row, reddit_case_study, ud_ratio,
)
from repro.core.accounting import GB, TB


def test_eq1_reddit_ledger():
    cs = reddit_case_study()
    assert cs["ud_ratio"] == pytest.approx(42.067, rel=2e-3)     # Eq. 1
    assert cs["cost_per_download"] == pytest.approx(4.42, abs=0.01)
    assert cs["http_bill"] == pytest.approx(424.32, rel=1e-3)
    assert cs["at_bill"] == pytest.approx(10.09, abs=0.01)


def test_table1_rows_match_paper():
    rows = {r.name: r for r in paper_table1()}
    # upload columns (100 downloads)
    assert rows["whale"].http_upload_bytes == pytest.approx(873.0 * GB)
    assert rows["whale"].at_upload_bytes == pytest.approx(20.68 * GB, rel=0.01)
    assert rows["diabetes"].http_upload_bytes == pytest.approx(8.22 * TB)
    assert rows["diabetes"].at_upload_bytes == pytest.approx(0.20 * TB, rel=0.03)
    assert rows["imagenet"].at_upload_bytes == pytest.approx(0.37 * TB, rel=0.02)
    # cost savings
    assert rows["whale"].cost_savings == pytest.approx(23.36, rel=0.01)
    assert rows["diabetes"].cost_savings == pytest.approx(220.68, rel=0.01)
    assert rows["imagenet"].cost_savings == pytest.approx(422.29, rel=0.01)
    # download times (hours; the paper's "m" column is a typo for hours)
    assert rows["whale"].http_hours == pytest.approx(4.85, rel=0.01)
    assert rows["whale"].at_hours == pytest.approx(0.07, abs=0.005)
    assert rows["diabetes"].http_hours == pytest.approx(45.66, rel=0.01)
    assert rows["diabetes"].at_hours == pytest.approx(0.67, abs=0.01)
    assert rows["imagenet"].http_hours == pytest.approx(87.39, rel=0.01)
    assert rows["imagenet"].at_hours == pytest.approx(1.28, abs=0.01)


def test_tracker_announce_scrape():
    mi = MetaInfo.from_bytes(b"z" * 4096, 1024)
    tr = Tracker()
    tr.register(mi)
    tr.announce(mi, "origin", uploaded=0, downloaded=0, event="started",
                is_origin=True)
    peers = tr.announce(mi, "p1", uploaded=0, downloaded=0, event="started")
    assert peers == ["origin"]
    tr.announce(mi, "p1", uploaded=100.0, downloaded=4096.0, event="completed")
    tr.announce(mi, "origin", uploaded=3996.0, downloaded=0, event="update",
                is_origin=True)
    st = tr.scrape(mi)
    assert st.seeders == 2 and st.leechers == 0 and st.completed == 1
    assert st.ud_ratio == pytest.approx(4096.0 / 3996.0)


def test_ud_ratio_edge_cases():
    assert ud_ratio(0.0, 0.0) == 0.0
    assert ud_ratio(10.0, 0.0) == float("inf")


def test_availability_map_counts_live_replicas():
    import numpy as np

    from repro.core import Bitfield

    mi = MetaInfo.from_bytes(b"z" * 4096, 1024)          # 4 pieces
    tr = Tracker()
    tr.register(mi)
    tr.announce(mi, "origin", uploaded=0, downloaded=0, event="started",
                is_origin=True)
    tr.attach_bitfield(mi, "origin", Bitfield.full(4))
    tr.announce(mi, "p1", uploaded=0, downloaded=0, event="started")
    tr.attach_bitfield(mi, "p1", Bitfield.from_indices(4, [0, 2]))
    tr.announce(mi, "p2", uploaded=0, downloaded=0, event="started")
    tr.attach_bitfield(mi, "p2", Bitfield.from_indices(4, [0]))

    avail = tr.availability_map(mi)
    assert avail.tolist() == [3, 1, 2, 1]
    # infrastructure excluded on request
    community = tr.availability_map(mi, include_origins=False)
    assert community.tolist() == [2, 0, 1, 0]
    # the map is a live view: bitfields mutate in place
    tr.announce(mi, "p2", uploaded=0, downloaded=4096.0, event="completed")
    for bf in [tr._bitfields[mi.info_hash]["p2"]]:
        bf.set(1), bf.set(2), bf.set(3)
    assert tr.availability_map(mi).tolist() == [3, 2, 3, 2]
    # departed peers stop counting
    tr.announce(mi, "p1", uploaded=0, downloaded=0, event="stopped")
    assert tr.availability_map(mi).tolist() == [2, 2, 2, 2]
    assert isinstance(avail, np.ndarray) and avail.dtype == np.int64


def test_availability_map_unknown_torrent_and_no_bitfields():
    mi = MetaInfo.from_bytes(b"z" * 4096, 1024)
    other = MetaInfo.from_bytes(b"q" * 2048, 1024)
    tr = Tracker()
    tr.register(mi)
    # registered but nobody attached a bitfield: all-zero map
    assert tr.availability_map(mi).tolist() == [0, 0, 0, 0]
    with pytest.raises(KeyError):
        tr.availability_map(other)
    with pytest.raises(KeyError):
        tr.attach_bitfield(other, "p1", None)


def test_announce_handouts_match_whole_swarm_filter_reference():
    """The O(sample) handout index must reproduce the old whole-swarm
    filter bit-for-bit: same eligible ordering (swarm-dict insertion
    order, stopped peers skipped, re-started peers back at their original
    slot) and the same seeded RNG draw per announce."""
    import numpy as np

    mi = MetaInfo.from_sizes_only(int(64e6), int(8e6), name="ref")

    def reference_handout(swarm, rng, peer_id, want_peers):
        eligible = [
            pid for pid, rec in swarm.items()
            if rec.peer_protocol and not rec.left and pid != peer_id
        ]
        if len(eligible) <= want_peers:
            return eligible
        idx = rng.choice(len(eligible), size=want_peers, replace=False)
        idx.sort()
        return [eligible[i] for i in idx]

    tr = Tracker(rng=np.random.default_rng(123))
    ref_rng = np.random.default_rng(123)
    tr.register(mi)
    script_rng = np.random.default_rng(7)
    alive = set()
    stopped = set()
    for step in range(400):
        roll = script_rng.random()
        if roll < 0.35 or not alive:
            pid = f"p{step:03d}"
            event = "started"
            pp = bool(script_rng.random() < 0.9)
        elif roll < 0.5 and alive:
            pid = sorted(alive)[int(script_rng.integers(len(alive)))]
            event = "stopped"
            pp = True
        elif roll < 0.6 and stopped:
            pid = sorted(stopped)[int(script_rng.integers(len(stopped)))]
            event = "started"  # re-join at the original insertion slot
            pp = True
        else:
            pid = sorted(alive)[int(script_rng.integers(len(alive)))]
            event = "update"
            pp = True
        want = int(script_rng.integers(1, 9))
        got = tr.announce(
            mi, pid, uploaded=0.0, downloaded=0.0, event=event,
            peer_protocol=pp, want_peers=want,
        )
        want_list = reference_handout(
            tr._swarm(mi), ref_rng, pid, want,
        )
        assert got == want_list, f"step {step} ({event} {pid})"
        if event == "stopped":
            alive.discard(pid)
            stopped.add(pid)
        else:
            alive.add(pid)
            stopped.discard(pid)

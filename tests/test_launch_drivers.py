"""Launch drivers (train/serve/elastic) end-to-end at CPU scale."""

import shutil
import sys

import pytest


def run_main(module, argv):
    old = sys.argv
    sys.argv = ["prog"] + argv
    try:
        module.main()
    finally:
        sys.argv = old


def test_launch_train_and_elastic(tmp_path, capsys):
    from repro.launch import elastic, train as launch_train

    ckpt = str(tmp_path / "ckpt")
    run_main(launch_train, [
        "--arch", "granite_3_2b", "--steps", "20", "--global-batch", "4",
        "--seq-len", "32", "--ckpt-dir", ckpt,
    ])
    out = capsys.readouterr().out
    assert "swarm ingest U/D" in out and "done step=20" in out

    run_main(elastic, ["--ckpt-dir", ckpt, "--arch", "granite_3_2b"])
    out = capsys.readouterr().out
    assert "resharded" in out and "data cursor" in out


def test_launch_train_crash_restart(tmp_path, capsys):
    from repro.launch import train as launch_train

    run_main(launch_train, [
        "--arch", "granite_3_2b", "--steps", "20", "--global-batch", "4",
        "--seq-len", "32", "--ckpt-dir", str(tmp_path / "c2"),
        "--crash-at", "12",
    ])
    out = capsys.readouterr().out
    assert "restart #1" in out and "done step=20 restarts=1" in out


def test_launch_serve(tmp_path, capsys):
    from repro.launch import serve as launch_serve

    run_main(launch_serve, [
        "--arch", "granite_3_2b", "--requests", "3", "--prompt-len", "8",
        "--new-tokens", "4", "--slots", "2",
    ])
    out = capsys.readouterr().out
    assert "tok/s" in out

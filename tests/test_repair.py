"""Durability tier: RepairController semantics, churn events, availability.

Covers the repair controller in isolation (hysteresis dead band, per-scan
budget, settlement/ledger accounting), read-repair end-to-end on the byte
engine (a poisoned at-rest replica is evicted — exactly that one), the
TraceChecker repair-causality invariant, the hardened EventSpec/ScenarioSpec
validation for ``churn_storm``/``pod_fail``, and the incremental tracker
availability map against its full-recompute reference under randomized
churn.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (
    ArrivalSpec,
    Bitfield,
    ContentSpec,
    EventSpec,
    ManifestSpec,
    MirrorSpec,
    FabricSpec,
    OriginPolicy,
    PodCacheSpec,
    RepairController,
    RepairSpec,
    ScenarioSpec,
    TopologySpec,
    TraceChecker,
    TraceEvent,
    Tracker,
)

MI, _ = ManifestSpec("unit", 1 << 20, 1 << 18, payload="size_only").build()
PIECE = float(1 << 18)


def controller(spec: RepairSpec, avail, fetched: list):
    """Controller over a mutable availability list and a fetch recorder."""
    seq = iter(range(10_000))

    def fetch(piece, now):
        fetched.append(piece)
        return f"dst{next(seq)}"

    return RepairController(
        spec, MI, availability=lambda: np.asarray(avail, dtype=np.int64),
        fetch=fetch,
    )


# ------------------------------------------------------------------ spec


def test_repair_spec_round_trip_including_inf_budget():
    spec = RepairSpec(target_replication=5, scan_interval=2.0,
                      budget_bps=12e6, hysteresis=1)
    assert RepairSpec.from_dict(spec.to_dict()) == spec
    # default budget is infinite: serialized as the string "inf" (strict
    # RFC 8259 — no Infinity token), parsed back to float('inf')
    d = RepairSpec().to_dict()
    assert d["budget_bps"] == "inf"
    json.dumps(d)  # must be plain JSON
    assert RepairSpec.from_dict(d) == RepairSpec()


@pytest.mark.parametrize("over", [
    dict(target_replication=0),
    dict(scan_interval=0.0),
    dict(budget_bps=0.0),
    dict(target_replication=2, hysteresis=2),
    dict(hysteresis=-1),
])
def test_repair_spec_validation(over):
    with pytest.raises(ValueError):
        RepairSpec(**over)


def test_scenario_spec_repair_round_trip():
    spec = ScenarioSpec(
        content=ContentSpec(manifests=(
            ManifestSpec("ds", 1 << 20, 1 << 17, payload="random"),
        )),
        fabric=FabricSpec(mirrors=(MirrorSpec("m0", up_bps=4e6),)),
        arrivals=(ArrivalSpec(kind="flash", n=4, up_bps=2e6, down_bps=4e6),),
        policy=OriginPolicy(swarm_fraction=1.0, origin_up_bps=4e6),
        repair=RepairSpec(target_replication=3, scan_interval=1.5),
        seed=3,
    )
    again = ScenarioSpec.from_json(spec.to_json())
    assert again == spec and again.repair.target_replication == 3
    # absent / null both mean "no repair tier"
    d = spec.to_dict()
    d["repair"] = None
    assert ScenarioSpec.from_dict(d).repair is None
    d.pop("repair")
    assert ScenarioSpec.from_dict(d).repair is None


# ------------------------------------------------------------- controller


def test_hysteresis_dead_band_no_thrash():
    # trigger is target - hysteresis: replication sitting inside the dead
    # band must not schedule anything, scan after scan
    fetched: list = []
    ctrl = controller(RepairSpec(target_replication=4, hysteresis=2),
                      [2, 3, 4], fetched)
    for t in range(5):
        assert ctrl.scan(float(t)) == 0
    assert fetched == [] and ctrl.pending_count == 0
    # ...but once a piece breaches the band it is restored all the way to
    # target (not just back inside the band), so it cannot re-trigger soon
    fetched.clear()
    ctrl = controller(RepairSpec(target_replication=4, hysteresis=2),
                      [1, 3, 4], fetched)
    assert ctrl.scan(0.0) == 3
    assert fetched == [0, 0, 0]


def test_most_degraded_piece_first():
    fetched: list = []
    ctrl = controller(RepairSpec(target_replication=3), [2, 0, 1], fetched)
    ctrl.scan(0.0)
    # piece 1 (avail 0) before piece 2 (avail 1) before piece 0 (avail 2)
    assert fetched == [1, 1, 1, 2, 2, 0]


def test_budget_caps_each_scan_without_carry_over():
    # allowance = budget_bps * scan_interval = 2 pieces per scan
    spec = RepairSpec(target_replication=6, scan_interval=1.0,
                      budget_bps=2 * PIECE)
    fetched: list = []
    ctrl = controller(spec, [0, 6, 6, 6], fetched)
    assert ctrl.scan(0.0) == 2          # capped by budget, not by deficit
    assert ctrl.scan(1.0) == 2          # in-flight counted, still capped
    assert len(fetched) == 4
    # an idle scan does not bank its unused allowance for the next one
    ctrl2 = controller(spec, [6, 6, 6, 6], fetched)
    assert ctrl2.scan(0.0) == 0
    ctrl2.availability = lambda: np.asarray([0, 6, 6, 6], dtype=np.int64)
    assert ctrl2.scan(1.0) == 2


def test_settlement_ledgers_by_tier_and_ignores_organic_transfers():
    fetched: list = []
    ctrl = controller(RepairSpec(target_replication=2), [0], fetched)
    assert ctrl.scan(0.0) == 2
    dsts = [k[0] for k in ctrl.pending]
    # an organic transfer (never scheduled) settles as a no-op
    assert ctrl.note_done("bystander", 0, "peer", PIECE, 1.0) is False
    assert ctrl.repairs_done == 0 and sum(ctrl.repair_bytes.values()) == 0
    # scheduled repairs settle and ledger bytes under their serving tier
    assert ctrl.note_done(dsts[0], 0, "origin", PIECE, 1.0) is True
    assert ctrl.note_done(dsts[1], 0, "pod_cache", PIECE, 1.5) is True
    assert ctrl.repairs_done == 2 and ctrl.pending_count == 0
    assert ctrl.repair_bytes == {"origin": PIECE, "pod_cache": PIECE,
                                 "peer": 0.0}


def test_failed_repair_is_rescheduled_by_the_next_scan():
    fetched: list = []
    ctrl = controller(RepairSpec(target_replication=1), [0, 1], fetched)
    assert ctrl.scan(0.0) == 1
    (dst, piece), = ctrl.pending
    assert ctrl.note_failed(dst, piece) is True
    assert ctrl.repairs_failed == 1 and ctrl.pending_count == 0
    # deficit still live, in-flight credit released: scheduled again
    assert ctrl.scan(1.0) == 1


def test_episode_tracking_measures_time_to_repair():
    avail = [[2, 2], [0, 2], [1, 2], [2, 2]]
    it = iter(avail)
    ctrl = RepairController(
        RepairSpec(target_replication=2), MI,
        availability=lambda: np.asarray(next(it), dtype=np.int64),
        fetch=lambda piece, now: None,   # nothing schedulable
    )
    for t in range(4):
        ctrl.scan(float(t))
    summ = ctrl.summary()
    assert summ["episodes"] == 1
    assert summ["time_to_repair"] == 2.0   # breached at t=1, healed at t=3
    assert summ["min_replication_low"] == 0.0
    assert summ["min_replication_final"] == 2.0


# ------------------------------------------------------------ read-repair


def byte_spec(**over) -> ScenarioSpec:
    base = dict(
        content=ContentSpec(manifests=(
            ManifestSpec("ds", 1 << 20, 1 << 17, payload="random"),
        )),
        fabric=FabricSpec(mirrors=(MirrorSpec("origin0", up_bps=8e6),)),
        arrivals=(ArrivalSpec(kind="flash", n=4, up_bps=2e6, down_bps=4e6),),
        policy=OriginPolicy(swarm_fraction=1.0, origin_up_bps=8e6),
        repair=RepairSpec(target_replication=2, scan_interval=1.0),
        seed=5,
    )
    base.update(over)
    return ScenarioSpec(**base)


def test_read_repair_evicts_exactly_the_poisoned_replica():
    compiled = byte_spec().build("byte")
    sw = compiled.sim
    mi = sw.metainfo
    # step until some peer's replica is wanted by another peer
    poisoned = None
    for _ in range(50):
        sw.step()
        sw.repair_scan()
        for pid in sorted(sw.peers):
            me = sw.peers[pid]
            if me.store is None:
                continue
            for piece in sorted(me.store):
                if any(oid != pid and piece not in sw.peers[oid].bitfield
                       for oid in sw.peers):
                    poisoned = (pid, piece)
                    break
            if poisoned:
                break
        if poisoned:
            break
    assert poisoned is not None, "no shareable replica ever appeared"
    pid, piece = poisoned
    holder = sw.peers[pid]
    good = holder.store[piece]
    holder.store[piece] = bytes([good[0] ^ 0xFF]) + good[1:]
    before = dict(holder.store)
    while not sw.complete:
        if sw.step() == 0 and sw.repair_scan() == 0:
            break
        sw.repair_scan()
    ctrl = sw.repair
    # the poisoned replica was detected and evicted — and only it; the
    # holder may legitimately re-fetch a *verified* copy afterward (it
    # still needs the piece), so assert on bytes, not on presence
    assert ctrl.evictions == 1
    if piece in holder.store:
        assert mi.verify_piece(piece, holder.store[piece])
    assert all(p in holder.store for p in before if p != piece)
    # nobody stored a corrupt piece, and every peer still completed
    for oid, agent in sw.peers.items():
        assert all(mi.verify_piece(i, d) for i, d in agent.store.items())
        assert sw._peer_done(oid)


# ----------------------------------------------------------- trace checker


def test_checker_flags_repair_done_without_schedule():
    events = [
        TraceEvent(0.0, "peer_join", torrent="a", client="p0"),
        TraceEvent(2.0, "repair_done", torrent="a", client="p0", piece=4,
                   nbytes=100.0, info="origin"),
    ]
    problems = TraceChecker(events).check()
    assert any("repair_done without a prior" in p for p in problems)
    events.insert(1, TraceEvent(
        1.0, "repair_scheduled", torrent="a", client="p0", piece=4,
        nbytes=100.0,
    ))
    assert TraceChecker(events).check() == []


# ------------------------------------------------------- event validation


def fabric_spec(**over) -> ScenarioSpec:
    base = dict(
        content=ContentSpec(manifests=(
            ManifestSpec("ds", 1 << 20, 1 << 17, payload="random"),
        )),
        fabric=FabricSpec(mirrors=(MirrorSpec("m0", up_bps=4e6),),
                          pod_caches=PodCacheSpec(up_bps=8e6)),
        topology=TopologySpec(num_pods=2, hosts_per_pod=4,
                              host_up_bps=2e6, host_down_bps=4e6,
                              spine_bps=float("inf")),
        arrivals=(ArrivalSpec(kind="flash", n=6, up_bps=2e6, down_bps=4e6,
                              topology_hosts=True),),
        policy=OriginPolicy(swarm_fraction=1.0, origin_up_bps=4e6),
        seed=2,
    )
    base.update(over)
    return ScenarioSpec(**base)


def test_churn_storm_and_pod_fail_round_trip():
    spec = fabric_spec(events=(
        EventSpec(kind="churn_storm", at=5.0, count=3, spread=2.0, seed=9),
        EventSpec(kind="pod_fail", at=8.0, pod=1),
    ))
    again = ScenarioSpec.from_json(spec.to_json())
    assert again == spec
    assert again.events[0].count == 3 and again.events[1].pod == 1


@pytest.mark.parametrize("kwargs,msg", [
    (dict(kind="meteor_strike", at=1.0), "unknown event kind"),
    (dict(kind="churn_storm", at=1.0, count=0), "count"),
    (dict(kind="churn_storm", at=1.0, count=2, spread=-1.0), "spread"),
    (dict(kind="churn_storm", at=1.0, count=2, target="p0"), "target"),
    (dict(kind="pod_fail", at=1.0), "pod"),
    (dict(kind="pod_fail", at=1.0, pod=0, target="m0"), "target"),
    (dict(kind="mirror_fail", at=1.0), "target"),
])
def test_event_spec_rejects_malformed_events(kwargs, msg):
    with pytest.raises(ValueError, match=msg):
        EventSpec(**kwargs)


def test_scenario_rejects_undeclared_targets_and_duplicates():
    with pytest.raises(ValueError, match="unknown mirror"):
        fabric_spec(events=(EventSpec(kind="mirror_fail", at=1.0,
                                      target="ghost"),))
    with pytest.raises(ValueError, match="undeclared pod"):
        fabric_spec(events=(EventSpec(kind="pod_fail", at=1.0, pod=7),))
    ev = EventSpec(kind="pod_fail", at=1.0, pod=0)
    with pytest.raises(ValueError, match="duplicate"):
        fabric_spec(events=(ev, EventSpec(kind="pod_fail", at=1.0, pod=0)))
    # same kind at a different time is a legitimate schedule, not a dup
    fabric_spec(events=(ev, EventSpec(kind="pod_fail", at=2.0, pod=0)))


def test_fleet_engine_rejects_repair_and_storm_events():
    with pytest.raises(ValueError, match="repair"):
        byte_spec().build("fleet")
    storm = byte_spec(repair=None, events=(
        EventSpec(kind="churn_storm", at=1.0, count=2),
    ))
    with pytest.raises(ValueError, match="object-engine only"):
        storm.build("fleet")


def test_repair_disabled_matches_repair_absent_exactly():
    base = byte_spec(repair=None).build("byte").run()
    off = byte_spec(repair=RepairSpec(enabled=False)).build("byte").run()
    a = next(iter(base.outcomes.values()))
    b = next(iter(off.outcomes.values()))
    assert base.sim_time == off.sim_time
    assert a.completed == b.completed and a.clients == b.clients


# ------------------------------------------------- incremental availability


def test_tracker_incremental_availability_matches_recompute_randomized():
    rng = np.random.default_rng(17)
    mi, _ = ManifestSpec("rand", 1 << 20, 1 << 17, payload="size_only").build()
    tracker = Tracker()
    tracker.register(mi)
    bitfields: dict[str, Bitfield] = {}
    alive: dict[str, bool] = {}

    def check():
        for inc in (True, False):
            got = tracker.availability_map(mi, include_origins=inc)
            want = tracker.availability_recompute(mi, include_origins=inc)
            np.testing.assert_array_equal(got, want)

    for step in range(300):
        op = rng.integers(0, 5)
        pid = f"p{rng.integers(0, 12)}"
        if op == 0:   # join (sometimes as infrastructure) + attach
            bf = Bitfield(mi.num_pieces)
            for i in rng.integers(0, mi.num_pieces, size=3):
                bf.set(int(i))
            tracker.announce(mi, pid, uploaded=0, downloaded=0,
                             event="started",
                             is_origin=bool(rng.integers(0, 4) == 0))
            tracker.attach_bitfield(mi, pid, bf)
            bitfields[pid] = bf
            alive[pid] = True
        elif op == 1 and alive.get(pid):   # churn out
            tracker.announce(mi, pid, uploaded=0, downloaded=0,
                             event="stopped")
            alive[pid] = False
        elif op == 2 and alive.get(pid):   # in-place bitfield mutation
            i = int(rng.integers(0, mi.num_pieces))
            bf = bitfields[pid]
            (bf.clear if i in bf else bf.set)(i)
        elif op == 3 and pid in bitfields:  # rejoin / re-announce
            tracker.announce(mi, pid, uploaded=0, downloaded=0,
                             event="started")
            alive[pid] = True
        elif op == 4 and alive.get(pid):   # re-attach a fresh object
            bf = Bitfield(mi.num_pieces)
            bf.set(int(rng.integers(0, mi.num_pieces)))
            tracker.attach_bitfield(mi, pid, bf)
            bitfields[pid] = bf
        if step % 7 == 0:
            check()
    check()

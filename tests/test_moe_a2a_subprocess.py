"""a2a MoE layout (§Perf HC1) vs local reference, on a real 8-device
multi-pod mesh (subprocess for its own device count)."""

import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, dataclasses
sys.path.insert(0, {src!r})
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models.layers import init_params
from repro.models.moe import EPContext, moe_apply, moe_specs
from repro.jax_compat import set_mesh

cfg = get_config("dbrx_132b").reduce(num_experts=4, top_k=2, d_model=32,
                                     d_ff=64, vocab_size=128)
cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # no drops => comparable
cfg_a2a = dataclasses.replace(cfg, moe_layout="a2a")
params = init_params(moe_specs(cfg), jax.random.key(0), jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, 32)), jnp.float32)
y_ref, aux_ref = moe_apply(params, x, cfg, EPContext())
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
with set_mesh(mesh):
    y, aux = jax.jit(lambda p, xx: moe_apply(p, xx, cfg_a2a, EPContext(mesh=mesh)))(params, x)
err = float(jnp.max(jnp.abs(np.asarray(y) - y_ref)))
assert err < 3e-2, err           # bf16 wire quantization bound
# lb is psum-MEANED over per-shard token pools (8 tokens each here) vs the
# local path's single 32-token pool — statistically different estimators
# of the same balance loss; require same ballpark only
assert abs(float(aux["lb"]) - float(aux_ref["lb"])) < 0.25
def loss(p):
    yy, aa = moe_apply(p, x, cfg_a2a, EPContext(mesh=mesh))
    return jnp.sum(yy ** 2) + aa["lb"]
with set_mesh(mesh):
    g = jax.jit(jax.grad(loss))(params)
gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
assert float(jnp.abs(g["w_down"]).sum()) > 0   # expert grads flow through a2a
print("OK", err)
"""


@pytest.mark.slow
def test_a2a_matches_local_on_multipod_mesh():
    import os
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=str(ROOT / "src"))],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout

"""Per-arch reduced-config smoke: forward + one train step, shapes + finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and EXPERIMENTS.md §Dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.train.train_step import init_train_state, make_train_step


def make_batch(cfg, rng, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.encoder_layers:
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(b, 16, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduce()
    bundle = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng)

    logits = bundle.forward_fn(bundle.init(jax.random.key(0)), batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(bundle, tcfg, jax.random.key(0))
    step = jax.jit(make_train_step(bundle, tcfg))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) == pytest.approx(np.log(cfg.vocab_size), rel=0.35)
    assert int(state2.opt.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
    )
    assert moved


@pytest.mark.parametrize("arch", ["arctic_480b", "dbrx_132b", "recurrentgemma_2b",
                                  "gemma2_2b", "mamba2_1_3b", "seamless_m4t_medium"])
def test_param_count_matches_published(arch):
    expected = {
        "arctic_480b": 477e9, "dbrx_132b": 132e9, "recurrentgemma_2b": 2.7e9,
        "gemma2_2b": 2.6e9, "mamba2_1_3b": 1.3e9, "seamless_m4t_medium": 0.6e9,
    }[arch]
    total, active = get_config(arch).param_count()
    assert total == pytest.approx(expected, rel=0.06)
    assert active <= total

"""int8 KV cache (kv_cache_dtype="int8"): halves decode cache bytes.

Acceptance mirrors KV-quantization literature (KIVI, KVQuant): small logit
perturbation, preserved argmax — not bitwise equality.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.transformer as tf
from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import dequantize_kv, quantize_kv
from repro.models.model import default_positions


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)) * 5.0, jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 16, 4, 1)
    err = jnp.max(jnp.abs(dequantize_kv(q, s) - x))
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float(err) <= float(jnp.max(amax)) / 127.0 * 1.01


def test_attention_level_error_bound():
    """decode attention with int8 cache vs bf16 cache: output error bounded
    by the quantization step (the right place for a tight bound — layer
    stacking amplifies it end-to-end)."""
    from repro.models.attention import decode_attention
    rng = np.random.default_rng(0)
    b, smax, hkv, d = 2, 32, 2, 64
    k = jnp.asarray(rng.normal(size=(b, smax, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, smax, hkv, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, 4, d)), jnp.float32)
    ref = decode_attention(q, k, v, jnp.int32(smax))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    got = decode_attention(
        q, dequantize_kv(kq, ks).astype(jnp.float32),
        dequantize_kv(vq, vs).astype(jnp.float32), jnp.int32(smax))
    err = float(jnp.max(jnp.abs(got - ref)))
    vmax = float(jnp.max(jnp.abs(v)))
    # v-error <= vmax/127; attention is a convex combination + k-side
    # perturbation of the weights — allow 6x the elementary step
    assert err < 6 * vmax / 127, (err, vmax)


@pytest.mark.parametrize("arch", ["granite_3_2b", "gemma2_2b"])
def test_int8_decode_close_to_full_precision(arch):
    cfg = get_config(arch).reduce(kv_cache_dtype="int8", head_dim=64)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    b, s = 2, 20
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full = bundle.forward_fn(params, {"tokens": toks})

    _, cache = bundle.prefill_fn(params, {"tokens": toks[:, : s - 1]})
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(cache))
    # cache payload is half the bf16 bytes (+ ~1/16 scale overhead)
    kv_bytes = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(cache)
        if l.dtype == jnp.int8
    )
    scale_bytes = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(cache)
        if l.dtype == jnp.bfloat16
    )
    assert scale_bytes < kv_bytes / 4

    cache = tf.pad_cache_to(cache, cfg, s + 4)
    pos = default_positions(cfg, b, 1, offset=s - 1)
    lg, _ = bundle.decode_fn(params, toks[:, s - 1 : s], pos, cache,
                             jnp.int32(s))
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, s - 1])))
    scale = float(jnp.max(jnp.abs(full[:, s - 1])))
    # end-to-end sanity: layer stacking amplifies the per-layer int8 noise;
    # at random init (near-uniform tiny logits) 10% relative is the
    # appropriate sanity band — the tight bound is attention-level above
    assert err / max(scale, 0.1) < 0.10, (err, scale)
    # greedy decisions mostly preserved even at random init
    agree = float((lg[:, 0].argmax(-1) == full[:, s - 1].argmax(-1)).mean())
    assert agree >= 0.5, agree

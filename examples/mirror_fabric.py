"""Walkthrough: from one melting mirror to a hierarchical delivery network.

Act 1 — the ImageNet problem: a dataset served from several university
mirrors with divergent bandwidth. The web-seed fabric spreads range
requests across them (least-loaded selection) while the swarm amplifies
every delivered byte, so the *aggregate* mirror bill stays ~1 copy.

Act 2 — inside the cluster: pods pulling the same dataset hammer the
spine. A pod-local cache proxy fills once per pod from the mirror tier and
serves its pod over leaf links — cross-pod traffic collapses to ~1 copy
per pod, measured on the shared spine link.

Act 3 — faults: the fastest mirror dies mid-download and one range arrives
corrupted; verified re-fetch + mirror failover deliver every byte intact.

Run:  PYTHONPATH=src python examples/mirror_fabric.py --hosts-per-pod 6
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import (
    ClusterTopology, MetaInfo, MirrorSpec, OriginPolicy, SwarmConfig,
    WebSeedSwarmSim, flash_crowd,
)


def act1_mirrors(args):
    size = args.size_gb * 1e9
    mi = MetaInfo.from_sizes_only(int(size), int(size / 64), name="imagenet")
    mirrors = [MirrorSpec("origin0", up_bps=12e6, weight=3.0),
               MirrorSpec("origin1", up_bps=6e6, weight=2.0),
               MirrorSpec("origin2", up_bps=2e6, weight=1.0)]
    print(f"Act 1 — {args.peers} clients, {args.size_gb:.2f} GB, "
          f"3 mirrors (12/6/2 MB/s), least-loaded selection")
    print(f"{'swarm fraction':>14s} {'aggregate egress':>17s} "
          f"{'per-mirror copies':>19s} {'mean dl':>8s}")
    for frac in (0.0, 0.5, 1.0):
        sim = WebSeedSwarmSim(
            mi, OriginPolicy(swarm_fraction=frac, selection="least_loaded"),
            SwarmConfig(), seed=0,
        )
        sim.add_mirrors(mirrors)
        sim.add_peers(flash_crowd(args.peers), up_bps=25e6, down_bps=50e6)
        res = sim.run()
        per = "/".join(
            f"{o.http_uploaded / mi.length:.2f}"
            for o in sim.origin_set.origins.values()
        )
        print(f"{frac:>14.2f} {res.origin_uploaded / mi.length:>10.2f} copies "
              f"{per:>19s} {res.mean_completion_time():>7.0f}s")


def act2_caches(args):
    size = args.size_gb * 1e9
    mi = MetaInfo.from_sizes_only(int(size), int(size / 64), name="cluster")
    pods = 2
    n = pods * args.hosts_per_pod
    print(f"\nAct 2 — {pods} pods x {args.hosts_per_pod} hosts, "
          f"spine-metered cross-pod traffic")
    print(f"{'stage':>10s} {'cross-pod/pod':>14s} {'mirror egress':>14s} "
          f"{'cache serves':>13s}")
    for stage in ("global", "locality", "cache"):
        topo = ClusterTopology(
            num_pods=pods, hosts_per_pod=args.hosts_per_pod,
            host_up_bps=25e6, host_down_bps=50e6, spine_bps=float("inf"),
        )
        frac = {"global": 0.5, "locality": 0.95, "cache": 1.0}[stage]
        sim = WebSeedSwarmSim(
            mi, OriginPolicy(swarm_fraction=1.0, origin_up_bps=20e6),
            SwarmConfig(max_neighbors=args.hosts_per_pod - 1),
            seed=1, topology=topo, same_pod_frac=frac,
        )
        sim.add_mirrors([MirrorSpec("origin0", up_bps=12e6),
                         MirrorSpec("origin1", up_bps=8e6)])
        if stage == "cache":
            sim.add_pod_caches(up_bps=100e6)
        sim.add_peers([(h.name, 0.0) for h in topo.hosts()],
                      up_bps=25e6, down_bps=50e6)
        res = sim.run()
        assert len(res.completion_time) == n
        print(f"{stage:>10s} "
              f"{res.cross_pod_bytes / mi.length / pods:>7.2f} copies "
              f"{res.origin_uploaded / mi.length:>7.2f} copies "
              f"{res.pod_cache_uploaded / mi.length:>6.2f} copies")


def act3_faults(args):
    payload = np.random.default_rng(0).integers(
        0, 256, size=1 << 21, dtype=np.uint8
    ).tobytes()
    mi = MetaInfo.from_bytes(payload, 1 << 16, name="faulty")
    store = dict(mi.split_pieces(payload))
    sim = WebSeedSwarmSim(
        mi, OriginPolicy(swarm_fraction=1.0, origin_up_bps=4e6),
        SwarmConfig(), seed=2, origin_payload=store,
    )
    sim.add_mirrors([MirrorSpec("origin0", up_bps=2e6, weight=2.0),
                     MirrorSpec("origin1", up_bps=2e6, weight=1.0)])
    sim.origin_set.origins["origin0"].corrupt_once.add(0)
    sim.add_peers(flash_crowd(6), up_bps=2e6, down_bps=4e6)
    sim.net.schedule(20.0, lambda now: sim.fail_mirror("origin0"))
    res = sim.run()
    verified = all(
        mi.verify_piece(i, d)
        for pid, a in sim.agents.items()
        if pid not in sim.origin_set.origins
        for i, d in a.store.items()
    )
    wasted = sum(l.wasted for l in res.ledgers.values())
    print(f"\nAct 3 — preferred mirror corrupted one range, then died at "
          f"t=20s:\n  {len(res.completion_time)}/6 clients finished; "
          f"{wasted / 1e3:.0f} kB re-fetched; all pieces verified: {verified}")
    assert verified and len(res.completion_time) == 6


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--peers", type=int, default=12)
    ap.add_argument("--hosts-per-pod", type=int, default=6)
    ap.add_argument("--size-gb", type=float, default=0.25)
    args = ap.parse_args()
    act1_mirrors(args)
    act2_caches(args)
    act3_faults(args)


if __name__ == "__main__":
    main()

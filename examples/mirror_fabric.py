"""Walkthrough: from one melting mirror to a hierarchical delivery network.

Act 1 — the ImageNet problem: a dataset served from several university
mirrors with divergent bandwidth. The web-seed fabric spreads range
requests across them (least-loaded selection) while the swarm amplifies
every delivered byte, so the *aggregate* mirror bill stays ~1 copy.

Act 2 — inside the cluster: pods pulling the same dataset hammer the
spine. A pod-local cache proxy fills once per pod from the mirror tier and
serves its pod over leaf links — cross-pod traffic collapses to ~1 copy
per pod, measured on the shared spine link.

Act 3 — faults, declared: the scenario's event timeline corrupts one range
and kills the fastest mirror mid-download; verified re-fetch + mirror
failover deliver every byte intact.

Every act is a ScenarioSpec — the same JSON-able values the benchmarks
commit under ``benchmarks/scenarios/``.

Run:  PYTHONPATH=src python examples/mirror_fabric.py --hosts-per-pod 6
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (
    ArrivalSpec, ContentSpec, EventSpec, FabricSpec, ManifestSpec,
    MirrorSpec, OriginPolicy, PodCacheSpec, ScenarioSpec, SwarmConfig,
    TopologySpec,
)


def act1_mirrors(args):
    size = int(args.size_gb * 1e9)
    scenario = ScenarioSpec(
        name="imagenet_mirrors",
        content=ContentSpec(manifests=(
            ManifestSpec("imagenet", size_bytes=size,
                         piece_length=size // 64),
        )),
        fabric=FabricSpec(mirrors=(
            MirrorSpec("origin0", up_bps=12e6, weight=3.0),
            MirrorSpec("origin1", up_bps=6e6, weight=2.0),
            MirrorSpec("origin2", up_bps=2e6, weight=1.0),
        )),
        arrivals=(ArrivalSpec(kind="flash", n=args.peers, up_bps=25e6,
                              down_bps=50e6),),
        policy=OriginPolicy(swarm_fraction=1.0, selection="least_loaded"),
        seed=0,
    )
    print(f"Act 1 — {args.peers} clients, {args.size_gb:.2f} GB, "
          f"3 mirrors (12/6/2 MB/s), least-loaded selection")
    print(f"{'swarm fraction':>14s} {'aggregate egress':>17s} "
          f"{'per-mirror copies':>19s} {'mean dl':>8s}")
    for frac in (0.0, 0.5, 1.0):
        point = dataclasses.replace(
            scenario,
            policy=dataclasses.replace(scenario.policy, swarm_fraction=frac),
        )
        out = point.build("time")
        res = out.run().primary
        per = "/".join(
            f"{o.http_uploaded / size:.2f}"
            for o in out.sim.origin_set.origins.values()
        )
        print(f"{frac:>14.2f} {res.origin_uploaded / size:>10.2f} copies "
              f"{per:>19s} {res.mean_completion_time():>7.0f}s")


def act2_caches(args):
    size = int(args.size_gb * 1e9)
    pods = 2
    n = pods * args.hosts_per_pod
    base = ScenarioSpec(
        name="cluster_caches",
        content=ContentSpec(manifests=(
            ManifestSpec("cluster", size_bytes=size, piece_length=size // 64),
        )),
        fabric=FabricSpec(mirrors=(
            MirrorSpec("origin0", up_bps=12e6),
            MirrorSpec("origin1", up_bps=8e6),
        )),
        topology=TopologySpec(num_pods=pods,
                              hosts_per_pod=args.hosts_per_pod,
                              host_up_bps=25e6, host_down_bps=50e6,
                              spine_bps=float("inf")),
        arrivals=(ArrivalSpec(kind="flash", n=n, up_bps=25e6, down_bps=50e6,
                              topology_hosts=True),),
        policy=OriginPolicy(swarm_fraction=1.0, origin_up_bps=20e6),
        swarm=SwarmConfig(max_neighbors=args.hosts_per_pod - 1),
        seed=1,
    )
    print(f"\nAct 2 — {pods} pods x {args.hosts_per_pod} hosts, "
          f"spine-metered cross-pod traffic")
    print(f"{'stage':>10s} {'cross-pod/pod':>14s} {'mirror egress':>14s} "
          f"{'cache serves':>13s}")
    for stage in ("global", "locality", "cache"):
        frac = {"global": 0.5, "locality": 0.95, "cache": 1.0}[stage]
        point = dataclasses.replace(
            base,
            topology=dataclasses.replace(base.topology, same_pod_frac=frac),
            fabric=dataclasses.replace(
                base.fabric,
                pod_caches=(PodCacheSpec(up_bps=100e6)
                            if stage == "cache" else None),
            ),
        )
        res = point.build("time").run().primary
        assert len(res.completion_time) == n
        print(f"{stage:>10s} "
              f"{res.cross_pod_bytes / size / pods:>7.2f} copies "
              f"{res.origin_uploaded / size:>7.2f} copies "
              f"{res.pod_cache_uploaded / size:>6.2f} copies")


def act3_faults(args):
    scenario = ScenarioSpec(
        name="fault_drill",
        content=ContentSpec(manifests=(
            ManifestSpec("faulty", size_bytes=1 << 21, piece_length=1 << 16,
                         payload="random"),
        )),
        fabric=FabricSpec(mirrors=(
            MirrorSpec("origin0", up_bps=2e6, weight=2.0),
            MirrorSpec("origin1", up_bps=2e6, weight=1.0),
        )),
        arrivals=(ArrivalSpec(kind="flash", n=6, up_bps=2e6,
                              down_bps=4e6),),
        policy=OriginPolicy(swarm_fraction=1.0, origin_up_bps=4e6),
        events=(
            EventSpec(kind="corrupt_once", target="origin0", piece=0),
            EventSpec(kind="mirror_fail", at=20.0, target="origin0"),
        ),
        seed=2,
    )
    out = scenario.build("time")
    res = out.run().primary
    sim = out.sim
    mi = sim.metainfo
    verified = all(
        mi.verify_piece(i, d)
        for pid, a in sim.agents.items()
        if pid not in sim.origin_set.origins
        for i, d in a.store.items()
    )
    wasted = sum(l.wasted for l in res.ledgers.values())
    print(f"\nAct 3 — preferred mirror corrupted one range, then died at "
          f"t=20s (both declared EventSpecs):\n  "
          f"{len(res.completion_time)}/6 clients finished; "
          f"{wasted / 1e3:.0f} kB re-fetched; all pieces verified: {verified}")
    assert verified and len(res.completion_time) == 6


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--peers", type=int, default=12)
    ap.add_argument("--hosts-per-pod", type=int, default=6)
    ap.add_argument("--size-gb", type=float, default=0.25)
    args = ap.parse_args()
    act1_mirrors(args)
    act2_caches(args)
    act3_faults(args)


if __name__ == "__main__":
    main()

"""End-to-end training driver: swarm-ingested data -> multi-step LM training
with checkpoint/restart.

Presets:
  smoke       ~1M params, 100 steps  (CI / seconds)
  cpu-small   ~10M params, 200 steps (a few minutes on this CPU container)
  paper-100m  ~100M params, 300 steps (the assignment's reference run —
              sized for a real accelerator host; runs on CPU if you wait)

Run:  PYTHONPATH=src python examples/train_lm.py --preset cpu-small \
          --arch granite_3_2b --steps 200
"""

import argparse
import shutil
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import TrainConfig
from repro.data import CorpusSpec, HostBatcher, ShardedCorpus, loader_from_corpus
from repro.models import build_model
from repro.train import FailurePlan, Trainer, TrainerConfig, run_with_restarts

PRESETS = {
    "smoke": dict(d_model=64, num_heads=4, head_dim=16, d_ff=128,
                  layers_mult=1, vocab=512, batch=8, seq=64, steps=100),
    "cpu-small": dict(d_model=256, num_heads=8, head_dim=32, d_ff=1024,
                      layers_mult=2, vocab=2048, batch=8, seq=128, steps=200),
    "paper-100m": dict(d_model=768, num_heads=12, head_dim=64, d_ff=3072,
                       layers_mult=4, vocab=8192, batch=16, seq=256, steps=300),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true",
                    help="keep existing checkpoints and resume")
    ap.add_argument("--inject-crash-at", type=int, default=None,
                    help="simulate a node failure at this step (demo of "
                    "checkpoint/restart)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    base = get_config(args.arch)
    cfg = base.reduce(
        d_model=p["d_model"], num_heads=p["num_heads"], head_dim=p["head_dim"],
        d_ff=p["d_ff"], vocab_size=p["vocab"],
        num_layers=len(base.block_pattern) * p["layers_mult"]
        + len(base.tail_pattern),
    )
    bundle = build_model(cfg)
    n_params = sum(
        int(__import__("numpy").prod(s.shape))
        for s in __import__("jax").tree.leaves(bundle.abstract())
    )
    steps = args.steps or p["steps"]
    print(f"arch={args.arch} preset={args.preset} params={n_params/1e6:.1f}M "
          f"steps={steps}")

    corpus = ShardedCorpus(CorpusSpec(
        num_shards=8, tokens_per_shard=max((p["seq"] + 1) * p["batch"] * 8, 1 << 15),
        vocab_size=p["vocab"],
    ))
    loader = loader_from_corpus(corpus, num_hosts=2, seed=0)
    rep = loader.ingest("full_replica")
    print(f"swarm ingest: U/D={rep.ud_ratio:.1f} rounds={rep.rounds}")
    shards = [loader.host_shard_tokens(0, s) for s in range(8)]
    batcher = HostBatcher(shards, batch_size=p["batch"], seq_len=p["seq"])

    if not args.resume:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    plan = FailurePlan(crash_at_steps=(args.inject_crash_at,)) \
        if args.inject_crash_at else None
    trainer = Trainer(
        bundle,
        TrainConfig(learning_rate=1e-3, warmup_steps=max(steps // 20, 5),
                    total_steps=steps),
        batcher,
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=max(steps // 5, 10),
                      log_every=max(steps // 20, 5)),
        failure_plan=plan,
    )
    final, restarts = run_with_restarts(
        lambda: trainer.run(steps).final_step, max_restarts=3,
        on_restart=lambda n, e: print(f"[supervisor] restart #{n} after {e}"),
    )
    print(f"done: step={final} restarts={restarts}")


if __name__ == "__main__":
    main()

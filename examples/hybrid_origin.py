"""Walkthrough: turning a plain HTTP mirror into a web-seed hybrid origin.

The paper's pitch in one script: a university mirror serving a dataset over
HTTP melts under a flash crowd; pointing the same clients at the same
server through the web-seed subsystem re-routes piece requests to other
downloaders, so origin egress collapses to ~1 copy while downloads get
faster. The whole deployment is *declared* once as a ScenarioSpec; the
sweep just replaces the swarm-routed fraction. Finishes with a cold start
from a bare origin with real verified bytes (the same scenario compiled to
the byte-domain engine).

Run:  PYTHONPATH=src python examples/hybrid_origin.py --peers 16
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (
    ArrivalSpec, ContentSpec, FabricSpec, ManifestSpec, MirrorSpec,
    OriginPolicy, ScenarioSpec, simulate_http,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--peers", type=int, default=16)
    ap.add_argument("--size-gb", type=float, default=1.0)
    ap.add_argument("--mode", default="swarm_first",
                    choices=["swarm_first", "http_first"])
    args = ap.parse_args()

    origin_bps = 20e6
    scenario = ScenarioSpec(
        name="hybrid_origin",
        content=ContentSpec(manifests=(
            ManifestSpec("mirror", size_bytes=int(args.size_gb * 1e9),
                         piece_length=int(16e6)),
        )),
        fabric=FabricSpec(mirrors=(MirrorSpec("origin", up_bps=origin_bps),)),
        arrivals=(ArrivalSpec(kind="flash", n=args.peers, up_bps=25e6,
                              down_bps=50e6),),
        policy=OriginPolicy(mode=args.mode, swarm_fraction=1.0,
                            origin_up_bps=origin_bps),
        seed=0,
    )
    mi, _ = scenario.content.manifests[0].build()
    arrivals = scenario.arrivals[0].generate()

    http = simulate_http(mi, arrivals, origin_bps, 50e6)
    print(f"{args.peers} clients, {args.size_gb:.1f} GB dataset, "
          f"{origin_bps / 1e6:.0f} MB/s origin ({args.mode})")
    print(f"{'swarm fraction':>14s} {'origin egress':>14s} "
          f"{'via HTTP':>10s} {'mean dl time':>13s} {'U/D':>6s}")
    print(f"{'pure HTTP':>14s} {http.origin_uploaded / 1e9:>11.1f} GB "
          f"{http.origin_uploaded / 1e9:>7.1f} GB "
          f"{http.mean_completion_time():>12.0f}s {'1.0':>6s}")
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        point = dataclasses.replace(
            scenario,
            policy=dataclasses.replace(scenario.policy, swarm_fraction=frac),
        )
        res = point.build("time").run().primary
        print(f"{frac:>14.2f} {res.origin_uploaded / 1e9:>11.1f} GB "
              f"{res.origin_http_uploaded / 1e9:>7.1f} GB "
              f"{res.mean_completion_time():>12.0f}s "
              f"{res.ud_ratio:>6.1f}")

    # byte-domain cold start: the same declarative API, real verified bytes,
    # bare origin, zero seeded peers
    cold = ScenarioSpec(
        name="cold_start",
        content=ContentSpec(manifests=(
            ManifestSpec("cold", size_bytes=1 << 22, piece_length=1 << 16,
                         payload="random"),
        )),
        fabric=FabricSpec(mirrors=(MirrorSpec("origin", up_bps=50e6),)),
        arrivals=(ArrivalSpec(kind="flash", n=8, up_bps=25e6, down_bps=50e6,
                              prefix="host"),),
        policy=OriginPolicy(swarm_fraction=1.0),
        seed=0,
    )
    result = cold.build("byte").run()
    out = result.outcomes["cold"]
    swarm = out.raw
    assert out.completed == 8
    print(f"\ncold start (byte-domain, 8 hosts, {(1 << 22) >> 20} MiB): "
          f"{result.sim_time:.0f} rounds, origin served "
          f"{swarm.http_uploaded / (1 << 22):.2f} copies over HTTP ranges, "
          f"swarm amplification U/D = {out.ud_ratio:.1f}")


if __name__ == "__main__":
    main()

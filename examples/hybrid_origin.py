"""Walkthrough: turning a plain HTTP mirror into a web-seed hybrid origin.

The paper's pitch in one script: a university mirror serving a dataset over
HTTP melts under a flash crowd; pointing the same clients at the same
server through the web-seed subsystem re-routes piece requests to other
downloaders, so origin egress collapses to ~1 copy while downloads get
faster. Sweeps the swarm-routed fraction, then shows a cold start from a
bare origin with real verified bytes (byte-domain engine).

Run:  PYTHONPATH=src python examples/hybrid_origin.py --peers 16
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import (
    LocalSwarm, MetaInfo, OriginPolicy, SwarmConfig, WebSeedSwarmSim,
    flash_crowd, simulate_http,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--peers", type=int, default=16)
    ap.add_argument("--size-gb", type=float, default=1.0)
    ap.add_argument("--mode", default="swarm_first",
                    choices=["swarm_first", "http_first"])
    args = ap.parse_args()

    size = args.size_gb * 1e9
    mi = MetaInfo.from_sizes_only(int(size), int(16e6), name="mirror")
    arrivals = flash_crowd(args.peers)
    origin_bps, peer_up, peer_down = 20e6, 25e6, 50e6

    http = simulate_http(mi, arrivals, origin_bps, peer_down)
    print(f"{args.peers} clients, {args.size_gb:.1f} GB dataset, "
          f"{origin_bps / 1e6:.0f} MB/s origin ({args.mode})")
    print(f"{'swarm fraction':>14s} {'origin egress':>14s} "
          f"{'via HTTP':>10s} {'mean dl time':>13s} {'U/D':>6s}")
    print(f"{'pure HTTP':>14s} {http.origin_uploaded / 1e9:>11.1f} GB "
          f"{http.origin_uploaded / 1e9:>7.1f} GB "
          f"{http.mean_completion_time():>12.0f}s {'1.0':>6s}")
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        sim = WebSeedSwarmSim(
            mi,
            OriginPolicy(mode=args.mode, swarm_fraction=frac,
                         origin_up_bps=origin_bps),
            SwarmConfig(), seed=0,
        )
        sim.add_web_origin()
        sim.add_peers(arrivals, up_bps=peer_up, down_bps=peer_down)
        res = sim.run()
        print(f"{frac:>14.2f} {res.origin_uploaded / 1e9:>11.1f} GB "
              f"{res.origin_http_uploaded / 1e9:>7.1f} GB "
              f"{res.mean_completion_time():>12.0f}s "
              f"{res.ud_ratio:>6.1f}")

    # byte-domain cold start: bare origin, zero seeded peers, real bytes
    payload = np.random.default_rng(0).integers(
        0, 256, size=1 << 22, dtype=np.uint8
    ).tobytes()
    small = MetaInfo.from_bytes(payload, 1 << 16, name="cold")
    swarm = LocalSwarm(
        small, dict(small.split_pieces(payload)),
        [f"host{i}" for i in range(8)], seed=0,
        webseed=OriginPolicy(swarm_fraction=1.0),
    )
    rounds = swarm.run()
    assert all(p.complete for p in swarm.peers.values())
    print(f"\ncold start (byte-domain, 8 hosts, {len(payload) >> 20} MiB): "
          f"{rounds} rounds, origin served "
          f"{swarm.http_uploaded / small.length:.2f} copies over HTTP ranges, "
          f"swarm amplification U/D = {swarm.ud_ratio:.1f}")


if __name__ == "__main__":
    main()

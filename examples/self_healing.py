"""Walkthrough: a swarm that notices decay and heals itself.

Act 1 — the silent failure mode: a flash crowd finishes, sessions end,
and a whole pod (cache included) loses power. Nothing is "down" — the
tracker still answers, the mirrors still serve — but the replica count
of the coldest pieces just fell off a cliff. We run the fault twice,
with and without the repair controller, and watch the fleet-wide minimum
replication through the metrics sampler.

Act 2 — the repair ledger: where did the healing bytes come from? The
controller prices every re-seed through the existing tier ladder
(mirrors -> pod caches -> peers) and ledgers bytes by serving tier, so
durability has a bill you can read.

Act 3 — churn storm: a burst of correlated departures (declared as a
single ``churn_storm`` EventSpec) against a population that does not
linger after finishing. The controller keeps re-seeding as the floor
moves under it.

Everything is a ScenarioSpec — the same JSON-able values committed under
``benchmarks/scenarios/durability.json`` and pinned by
``BENCH_durability.json``.

Run:  PYTHONPATH=src python examples/self_healing.py
"""

import argparse
import dataclasses
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import EventSpec, ScenarioSpec, TelemetrySpec

SCENARIO = (Path(__file__).resolve().parent.parent / "benchmarks"
            / "scenarios" / "durability.json")

TELEMETRY = TelemetrySpec(enabled=True, trace=False, metrics=True,
                          sample_interval=1.0)


def replication_floor(result):
    s = result.metrics.series()
    return s["t"], s["min_replication"]


def act1_pod_loss(spec):
    target = spec.repair.target_replication
    print(f"Act 1 — pod 2 (cache + 6 clients) dies at t=10s mid-crowd; "
          f"target replication {target}")
    print(f"{'t':>4s} {'with repair':>12s} {'without':>8s}")
    runs = {}
    for label, point in (
        ("repair", dataclasses.replace(spec, telemetry=TELEMETRY)),
        ("organic", dataclasses.replace(spec, repair=None,
                                        telemetry=TELEMETRY)),
    ):
        compiled = point.build("time")
        runs[label] = (compiled, compiled.run())
    t_r, m_r = replication_floor(runs["repair"][1])
    t_o, m_o = replication_floor(runs["organic"][1])
    for t in range(8, 18):
        r = m_r[np.searchsorted(t_r, t)] if t <= t_r[-1] else m_r[-1]
        o = m_o[np.searchsorted(t_o, t)] if t <= t_o[-1] else m_o[-1]
        marker = "  <- fault" if t == 10 else ""
        print(f"{t:>3d}s {r:>12.0f} {o:>8.0f}{marker}")
    return runs["repair"][0]


def act2_ledger(compiled):
    sim = compiled.sim
    ctrl = compiled.repairs[sim.metainfo.name]
    summ = ctrl.summary()
    print(f"\nAct 2 — the repair bill, by serving tier "
          f"({summ['repairs_done']} re-seeds, episode closed in "
          f"{summ['time_to_repair']:.0f}s):")
    for tier, nbytes in summ["repair_bytes"].items():
        bar = "#" * int(nbytes / 5e5)
        print(f"  {tier:>10s} {nbytes / 1e6:>6.2f} MB {bar}")
    mi = sim.metainfo
    corrupt = sum(
        1
        for pid, a in sim.agents.items()
        if pid not in sim.origin_set.origins and a.store is not None
        for i, d in a.store.items()
        if not mi.verify_piece(i, d)
    )
    print(f"  corrupt replicas at rest: {corrupt} "
          f"(read-repair evicted {summ['evictions']})")
    assert corrupt == 0


def act3_churn_storm(spec):
    point = dataclasses.replace(
        spec,
        telemetry=TELEMETRY,
        arrivals=(dataclasses.replace(spec.arrivals[0], seed_linger=0.0),),
        events=(EventSpec(kind="churn_storm", at=8.0, count=6, spread=2.0,
                          seed=23),),
    )
    compiled = point.build("time")
    compiled.run()
    ctrl = compiled.repairs[compiled.sim.metainfo.name]
    summ = ctrl.summary()
    print(f"\nAct 3 — churn storm: 6 sessions end in a ~2s burst at t=8s, "
          f"finished peers leave immediately:\n  "
          f"floor dipped to {summ['min_replication_low']:.0f} replicas; "
          f"{summ['repairs_done']} re-seeds scheduled against the shrinking "
          f"swarm ({summ['repairs_failed']} lost to further churn)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", type=Path, default=SCENARIO,
                    help="durability ScenarioSpec JSON to replay")
    args = ap.parse_args()
    spec = ScenarioSpec.load(args.scenario)
    compiled = act1_pod_loss(spec)
    act2_ledger(compiled)
    act3_churn_storm(spec)


if __name__ == "__main__":
    main()

"""Batched serving example: prefill + decode with continuous batching.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma2_2b
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduce()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    engine = ServeEngine(bundle, params, ServeConfig(
        max_new_tokens=args.new_tokens, temperature=args.temperature))

    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32)
            for _ in range(args.requests)]
    import time
    t0 = time.perf_counter()
    outs = engine.serve_queue(reqs, slots=args.slots)
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    for i, o in enumerate(outs[:3]):
        print(f"req{i}: prompt={reqs[i][:6]}... -> {o}")
    print(f"{args.requests} requests x {args.new_tokens} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s on CPU, {args.slots} slots)")


if __name__ == "__main__":
    main()

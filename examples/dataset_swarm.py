"""The paper's own scenario: N researchers downloading a dataset, HTTP vs
HTTP+P2P, with live U/D accounting (Eq. 1) and Table-1-style projection.

The deployment is one declarative ScenarioSpec: a single origin that also
speaks the peer protocol (``serve_peer_protocol=True`` at swarm fraction 1
is exactly the paper's seeded-origin swarm), staggered researcher
arrivals that linger seeding for an hour after finishing.

Run:  PYTHONPATH=src python examples/dataset_swarm.py --downloads 24
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (
    ArrivalSpec, ContentSpec, FabricSpec, ManifestSpec, MirrorSpec,
    OriginPolicy, ScenarioSpec, accounting, project_row, simulate_http,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--downloads", type=int, default=24)
    ap.add_argument("--size-gb", type=float, default=8.0)
    args = ap.parse_args()

    size = args.size_gb * 1e9
    scenario = ScenarioSpec(
        name="dataset_swarm",
        content=ContentSpec(manifests=(
            ManifestSpec("dataset", size_bytes=int(size),
                         piece_length=int(32e6)),
        )),
        fabric=FabricSpec(mirrors=(MirrorSpec("origin", up_bps=10e6),)),
        arrivals=(ArrivalSpec(kind="staggered", n=args.downloads,
                              interval=120.0, up_bps=25e6, down_bps=50e6,
                              seed_linger=3600.0),),
        policy=OriginPolicy(swarm_fraction=1.0, origin_up_bps=10e6,
                            serve_peer_protocol=True),
        seed=0,
    )
    mi, _ = scenario.content.manifests[0].build()
    arrivals = scenario.arrivals[0].generate()

    http = simulate_http(mi, arrivals, origin_up_bps=10e6,
                         client_down_bps=50e6)
    res = scenario.build("time").run().primary

    cost = accounting.CostModel()
    print(f"dataset: {args.size_gb:.1f} GB, {args.downloads} downloads")
    print(f"{'':16s}{'origin egress':>16s}{'origin bill':>14s}{'mean dl time':>14s}")
    print(f"{'HTTP':16s}{http.origin_uploaded/1e9:>13.1f} GB"
          f"{cost.egress_cost(http.origin_uploaded):>13.2f}$"
          f"{http.mean_completion_time():>13.0f}s")
    print(f"{'HTTP + swarm':16s}{res.origin_uploaded/1e9:>13.1f} GB"
          f"{cost.egress_cost(res.origin_uploaded):>13.2f}$"
          f"{res.mean_completion_time():>13.0f}s")
    print(f"\nmeasured U/D (Eq. 1) = {res.ud_ratio:.1f}")
    row = project_row("this-dataset", size, 100, res.ud_ratio)
    print(f"Table-1-style projection at 100 downloads: save "
          f"${row.cost_savings:.2f} in egress; "
          f"{row.http_hours:.2f}h -> {row.at_hours:.3f}h per download")


if __name__ == "__main__":
    main()

"""Walkthrough: tail latency, and hedging it away.

A download is only as fast as its slowest piece. When one mirror is slow
(mis-provisioned, far away, overloaded) and the client's selection policy
prefers it, the whole crowd's p99 completion time crawls at that mirror's
pace. Client-side **mirror hedging** — the HTTP analogue of endgame mode —
duplicates tail range requests to the next ranked mirror, cancels the
loser, and ledgers the cancelled bytes as an explicit insurance premium.

The slow-mirror deployment is declared once as a ScenarioSpec; the unhedged
and hedged runs are the same scenario with one policy knob flipped.

Run:  PYTHONPATH=src python examples/tail_hedging.py --peers 12
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (
    ArrivalSpec, ContentSpec, FabricSpec, ManifestSpec, MirrorSpec,
    OriginPolicy, ScenarioSpec,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--peers", type=int, default=12)
    ap.add_argument("--size-gb", type=float, default=0.25)
    ap.add_argument("--tail", type=float, default=0.25,
                    help="hedge_tail_fraction (fraction of pieces hedged)")
    args = ap.parse_args()
    size = int(args.size_gb * 1e9)

    # static weights prefer the slow mirror — the realistic "nearest mirror
    # is not the fastest mirror" trap
    scenario = ScenarioSpec(
        name="tail_hedging",
        content=ContentSpec(manifests=(
            ManifestSpec("tail", size_bytes=size, piece_length=size // 32),
        )),
        fabric=FabricSpec(mirrors=(
            MirrorSpec("near", up_bps=3e6, weight=2.0),
            MirrorSpec("far", up_bps=60e6, weight=1.0),
        )),
        arrivals=(ArrivalSpec(kind="flash", n=args.peers, up_bps=25e6,
                              down_bps=50e6),),
        policy=OriginPolicy(swarm_fraction=0.0, origin_up_bps=3e6,
                            selection="static",
                            hedge_tail_fraction=args.tail),
        seed=7,
    )

    print(f"{args.peers} clients, {args.size_gb:.2f} GB, slow preferred "
          f"mirror (3 MB/s) + fast alternate (60 MB/s)")
    print(f"{'mode':>10s} {'p50':>7s} {'p95':>7s} {'p99':>7s} "
          f"{'premium':>10s}")
    results = {}
    for hedge in (False, True):
        point = dataclasses.replace(
            scenario,
            policy=dataclasses.replace(scenario.policy, hedge=hedge),
        )
        res = point.build("time").run().primary
        assert len(res.completion_time) == args.peers
        results[hedge] = res
        pct = res.completion_percentiles()
        label = "hedged" if hedge else "unhedged"
        print(f"{label:>10s} {pct['p50']:>6.0f}s {pct['p95']:>6.0f}s "
              f"{pct['p99']:>6.0f}s "
              f"{res.hedge_cancelled_bytes / 1e6:>8.1f}MB")

    off, on = results[False], results[True]
    p99_off = off.completion_percentiles()["p99"]
    p99_on = on.completion_percentiles()["p99"]
    counts, edges = on.fetch_latency_histogram(bins=8)
    print(f"\nhedging cut p99 by {(1 - p99_on / p99_off) * 100:.0f}% "
          f"({p99_off:.0f}s -> {p99_on:.0f}s) for "
          f"{on.hedge_cancelled_bytes / size:.3f} copies of premium")
    print(f"hedged fetch-latency histogram (s): "
          + " ".join(f"{e:.0f}:{c}" for e, c in zip(edges, counts)))
    assert p99_on < p99_off
    assert on.hedge_cancelled_bytes > 0 and off.hedge_cancelled_bytes == 0


if __name__ == "__main__":
    main()

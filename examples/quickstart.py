"""Quickstart: declare a delivery scenario, swarm a dataset, train on it.

The 60-second tour of the whole system, now through the declarative API:
  1. build a synthetic sharded corpus; its manifest IS a torrent;
  2. *declare* the delivery deployment as a ScenarioSpec — one JSON-able
     value holding content, mirror fabric, policy, arrivals, and a fault
     timeline — and compile it to the time-domain engine (watch origin
     egress collapse to ~1 copy while a mirror dies mid-download);
  3. distribute the corpus to 4 "hosts" through the verified byte-level
     swarm and train a small LM on the swarm-ingested tokens;
  4. checkpoint it, and broadcast the checkpoint bundle through the swarm.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import shutil
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import (
    ArrivalSpec, ContentSpec, EventSpec, FabricSpec, LocalSwarm,
    ManifestSpec, MirrorSpec, OriginPolicy, ScenarioSpec,
)
from repro.data import CorpusSpec, HostBatcher, ShardedCorpus, loader_from_corpus
from repro.models import build_model
from repro.train import Trainer, TrainerConfig, checkpoint_metainfo


def main() -> None:
    print("=== 1. publish a dataset (manifest == torrent) ===")
    spec = CorpusSpec(num_shards=8, tokens_per_shard=1 << 14,
                      piece_length=1 << 14, vocab_size=512)
    corpus = ShardedCorpus(spec)
    print(f"corpus: {spec.total_tokens} tokens in {spec.num_shards} shards, "
          f"{corpus.manifest.num_pieces} pieces, "
          f"infohash {corpus.manifest.info_hash_hex[:16]}…")

    print("\n=== 2. declare the delivery scenario (one serializable value) ===")
    scenario = ScenarioSpec(
        name="quickstart",
        content=ContentSpec(manifests=(
            ManifestSpec("release", size_bytes=int(256e6),
                         piece_length=int(8e6)),
        )),
        fabric=FabricSpec(mirrors=(
            MirrorSpec("mirror-a", up_bps=12e6, weight=2.0),
            MirrorSpec("mirror-b", up_bps=8e6, weight=1.0),
        )),
        arrivals=(ArrivalSpec(kind="flash", n=12, up_bps=25e6,
                              down_bps=50e6),),
        policy=OriginPolicy(swarm_fraction=1.0, origin_up_bps=20e6,
                            selection="least_loaded"),
        events=(EventSpec(kind="mirror_fail", at=30.0, target="mirror-a"),),
        seed=0,
    )
    blob = scenario.to_json()
    print(f"scenario JSON: {len(blob)} bytes; round-trips: "
          f"{ScenarioSpec.from_json(blob) == scenario}")
    result = scenario.build("time").run()
    out = result.outcomes["release"]
    print(f"flash crowd of {out.clients}: {out.completed} completed in "
          f"{out.duration:.0f}s despite mirror-a dying at t=30s; "
          f"origin served {out.origin_uploaded / 256e6:.2f} copies "
          f"(U/D {out.ud_ratio:.1f}x, Eq. 1)")

    print("\n=== 3. swarm the corpus to 4 hosts, train a small LM ===")
    loader = loader_from_corpus(corpus, num_hosts=4, seed=0)
    rep = loader.ingest("full_replica")
    print(f"origin uploaded {rep.origin_uploaded/1e6:.1f} MB for "
          f"{rep.total_downloaded/1e6:.1f} MB delivered "
          f"(U/D amplification {rep.ud_ratio:.1f}x)")
    cfg = get_config("granite_3_2b").reduce(vocab_size=512)
    bundle = build_model(cfg)
    shards = [loader.host_shard_tokens(0, s) for s in range(spec.num_shards)]
    batcher = HostBatcher(shards, batch_size=8, seq_len=64)
    ckpt_dir = "/tmp/repro_quickstart_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    trainer = Trainer(bundle, TrainConfig(learning_rate=3e-3, warmup_steps=5,
                                          total_steps=60),
                      batcher, TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=30,
                                             log_every=15))
    report = trainer.run(60)
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")

    print("\n=== 4. broadcast the checkpoint through the swarm ===")
    mi, payload = checkpoint_metainfo(ckpt_dir, 60, piece_length=1 << 16)
    swarm = LocalSwarm(mi, dict(mi.split_pieces(payload)),
                       [f"host{i}" for i in range(4)], seed=0)
    rounds = swarm.run()
    print(f"checkpoint {mi.length/1e6:.1f} MB replicated to 4 hosts in "
          f"{rounds} rounds; origin served "
          f"{swarm.origin.ledger.uploaded/1e6:.1f} MB "
          f"(U/D {swarm.ud_ratio:.1f}x)")
    print("\nall four stages OK")


if __name__ == "__main__":
    main()

"""Checkpoint distribution three ways: origin-only vs swarm vs
collective-assisted (ICI all-gather) — the paper's Table-1 economics
applied to model weights.

Run:  PYTHONPATH=src python examples/checkpoint_broadcast.py
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core import (
    ClusterTopology, LocalSwarm, MetaInfo, broadcast_bundle, bundle_to_bytes,
    coldstart_time,
)
from repro.kernels.checksum import device_checksum, verify_replicas
from repro.launch.mesh import make_test_mesh


def main() -> None:
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, 4 << 20, np.uint8).tobytes()  # 4 MB demo
    mi = MetaInfo.from_bytes(payload, 1 << 16, name="ckpt_demo_0")
    print(f"bundle: {mi.length/1e6:.1f} MB, {mi.num_pieces} pieces")

    print("\n--- functional swarm broadcast to 8 hosts (verified bytes) ---")
    t0 = time.perf_counter()
    swarm = LocalSwarm(mi, dict(mi.split_pieces(payload)),
                       [f"host{i}" for i in range(8)], seed=0)
    rounds = swarm.run()
    print(f"rounds={rounds} origin_served={swarm.origin.ledger.uploaded/1e6:.1f}MB "
          f"ud={swarm.ud_ratio:.1f} wall={time.perf_counter()-t0:.2f}s")

    print("\n--- collective-assisted: stripe + all-gather on a jax mesh ---")
    mesh = make_test_mesh((1, 1), ("data", "model"))
    replicated, ln = broadcast_bundle(payload, mesh, "data")
    assert bundle_to_bytes(replicated, ln) == payload
    cs = device_checksum(replicated)
    print(f"replicated on-mesh; device checksum={np.asarray(cs)} "
          f"replicas_agree={verify_replicas([cs, cs])}")

    print("\n--- projected wall times, 512-host fleet, 1 TB checkpoint ---")
    topo = ClusterTopology(num_pods=2, hosts_per_pod=256)
    for strat in ("origin_only", "swarm", "collective"):
        est = coldstart_time(topo, 1e12, strat)
        print(f"{strat:12s} t={est.seconds:8.1f}s  origin_egress="
          f"{est.origin_bytes/1e12:7.2f} TB")


if __name__ == "__main__":
    main()

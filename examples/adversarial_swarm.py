"""Walkthrough: a swarm under attack, and the quarantine that contains it.

Act 1 — Byzantine poisoners: 10% of the flash crowd corrupts every piece
it serves over the peer wire (their at-rest replicas stay good — this is
wire-level sabotage, not bit rot). Every verify failure is attributed to
the serving source; past the hash-fail threshold the quarantine bans the
peer, the tracker stops handing it out, and its mesh connections drop.
We watch the strike ledger fill and the bans land.

Act 2 — tracker blackout: the control plane goes dark for 30 s mid-crowd
(``tracker_fail``/``tracker_heal`` events). Clients ride their cached
peer lists and re-announce with capped exponential backoff plus
deterministic per-peer jitter; the data plane never stops. We compare
completion against an outage-free baseline.

Act 3 — partition: a pod is cut from the spine mid-download and healed
14 s later. In-flight cross-partition flows abort and retry inside the
side; on heal the two sides reconcile and everyone finishes.

Everything is a ScenarioSpec — the same JSON-able values committed under
``benchmarks/scenarios/adversarial.json`` and pinned by
``BENCH_adversarial.json``.

Run:  PYTHONPATH=src python examples/adversarial_swarm.py
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import EventSpec, ScenarioSpec, TopologySpec

SCENARIO = (Path(__file__).resolve().parent.parent / "benchmarks"
            / "scenarios" / "adversarial.json")


def act1_poisoners(spec):
    point = dataclasses.replace(spec, events=())
    poisoners = point.resolve_poisoners()
    print(f"Act 1 — {len(poisoners)} of {point.arrivals[0].n} clients are "
          f"poisoners ({', '.join(poisoners)}); "
          f"ban threshold {point.adversary.ban_threshold} strikes")
    compiled = point.build("time")
    result = compiled.run()
    q = compiled.quarantines[compiled.sim.metainfo.name]
    out = next(iter(result.outcomes.values()))
    print(f"  completed {out.completed}/{out.clients} in {out.duration:.0f}s")
    for pid in poisoners:
        strikes = q.fails.get(pid, 0)
        banned = "BANNED" if q.is_banned(pid) else "live"
        print(f"  {pid}: {strikes} strikes -> {banned}")
    print(f"  poisoned waste: {q.wasted_bytes / 1e6:.2f} MB thrown away "
          f"({q.wasted_bytes / out.total_downloaded * 100:.1f}% of goodput)")
    assert set(q.banned) == set(poisoners)
    mi = compiled.sim.metainfo
    corrupt = sum(
        1
        for pid, a in compiled.sim.agents.items()
        if pid not in compiled.sim.origin_set.origins and a.store is not None
        for i, d in a.store.items()
        if not mi.verify_piece(i, d)
    )
    print(f"  corrupt bytes in finished pieces: {corrupt}")
    assert corrupt == 0


def act2_blackout(spec):
    print("\nAct 2 — tracker dark from t=10s to t=40s, honest swarm:")
    honest = dataclasses.replace(spec, adversary=None, events=())
    dark = dataclasses.replace(spec, adversary=None)
    th = next(iter(honest.build("time").run().outcomes.values())).duration
    res = dark.build("time").run()
    out = next(iter(res.outcomes.values()))
    print(f"  healthy baseline: all done in {th:.0f}s")
    print(f"  30s blackout:     {out.completed}/{out.clients} done in "
          f"{out.duration:.0f}s (delta {out.duration - th:+.1f}s — cached "
          f"peer lists kept the data plane flowing)")
    assert out.completed == out.clients


def act3_partition(spec):
    print("\nAct 3 — pod 1 cut from the spine t=8s..22s:")
    point = dataclasses.replace(
        spec,
        adversary=None,
        topology=TopologySpec(num_pods=2, hosts_per_pod=10,
                              host_up_bps=2e6, host_down_bps=4e6,
                              spine_bps=float("inf"), same_pod_frac=0.8),
        arrivals=(dataclasses.replace(spec.arrivals[0],
                                      topology_hosts=True),),
        events=(
            EventSpec(kind="partition", at=8.0, target="pods:1"),
            EventSpec(kind="partition_heal", at=22.0, target="pods:1"),
        ),
    )
    compiled = point.build("time")
    result = compiled.run()
    out = next(iter(result.outcomes.values()))
    print(f"  {out.completed}/{out.clients} completed in {out.duration:.0f}s; "
          f"cross-partition flows aborted and retried in-side, both sides "
          f"reconciled on heal")
    assert out.completed == out.clients
    assert not compiled.sim.net.partitioned


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", type=Path, default=SCENARIO,
                    help="adversarial ScenarioSpec JSON to replay")
    args = ap.parse_args()
    spec = ScenarioSpec.load(args.scenario)
    act1_poisoners(spec)
    act2_blackout(spec)
    act3_partition(spec)


if __name__ == "__main__":
    main()
